"""§Roofline: render the per-(arch × shape) table from the dry-run JSONs.

roofline_fraction = time the chip MUST spend on model math
                    (MODEL_FLOPS / chips / peak) ÷ the binding resource
                    term of the compiled step — i.e. how much of the
                    step's best-case (perfectly overlapped) wall time is
                    mandatory model compute. This is the score §Perf
                    hillclimbs push up by driving the dominant term down.
"""
from __future__ import annotations

import glob
import json
import os

HW = dict(peak=197e12, hbm=819e9, ici=50e9)


def load(dirname="experiments/dryrun", mesh="single"):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, f"*_{mesh}.json"))):
        rows.append(json.load(open(f)))
    return rows


def fraction(rec) -> float:
    t = rec["roofline"]
    bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
    useful_s = rec["model_flops"] / rec["chips"] / HW["peak"]
    return useful_s / bound if bound else 0.0


def render(rows, print_fn=print):
    print_fn(
        "arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
        "model_flops,useful_ratio,roofline_fraction,peak_mem_GiB"
    )
    for r in rows:
        if r.get("status") == "skipped":
            print_fn(f"{r['arch']},{r['shape']},{r['mesh']},SKIP,,,,,,,")
            continue
        if r.get("status") != "ok":
            print_fn(f"{r['arch']},{r['shape']},{r['mesh']},FAILED,,,,,,,")
            continue
        t = r["roofline"]
        print_fn(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{t['compute_s']:.3e},{t['memory_s']:.3e},{t['collective_s']:.3e},"
            f"{t['dominant']},{r['model_flops']:.3e},"
            f"{r['useful_flops_ratio']:.3f},{fraction(r):.3f},"
            f"{r['memory']['peak_estimate_bytes']/2**30:.2f}"
        )


def run(print_fn=print):
    rows = load()
    if not rows:
        print_fn("# no dry-run records found; run: python -m repro.launch.dryrun --all")
        return []
    print_fn("# Roofline table (single-pod 16x16, per-device terms)")
    render(rows, print_fn)
    return rows
