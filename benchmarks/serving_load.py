"""§Serving — open-loop Poisson-arrival load on the continuous-batching
serving core: p50/p99 TTFT and per-token decode latency.

Open-loop means arrivals follow their own (Poisson) schedule regardless
of completions — the honest way to load a latency-critical server,
since closed-loop drivers self-throttle and hide queueing delay. Each
request gets a random prompt length and token budget, so the run
exercises divergent per-slot cache lengths and slot reuse.

The shared-prefix mode (``run_shared_prefix`` / ``--shared-prefix``)
drives the paged engine with prompts sharing one long header (a system
prompt), once with prefix reuse on and once off, on an identical
workload: it reports the hit rate and p50/p99 TTFT both ways, verifies
the two runs decode token-identically, and asserts a nonzero hit rate
(the CI smoke contract). A mid-size config is used so prefill compute —
the cost reuse removes — dominates per-call dispatch overhead.

Feeds the ``serving`` section of ``BENCH_aira.json`` (benchmarks/run.py)
so serving latency is tracked across PRs. Request generation lives in
``repro.serve.load`` (shared with examples/serve_decode.py).

Usage: PYTHONPATH=src python -m benchmarks.serving_load [--shared-prefix]
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


def run(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 12,
    rate_rps: float = 20.0,
    max_batch: int = 4,
    tokens: int = 8,
    seed: int = 0,
    print_fn=print,
) -> dict:
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.serve.load import make_requests

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    engine = ServingEngine(model, params, max_seq=64)
    rng = np.random.default_rng(seed)
    reqs = make_requests(
        n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens, rng=rng
    )
    outputs = engine.serve(reqs, max_batch=max_batch, seed=seed)
    assert all(r.finished for r in reqs)
    assert all(len(outputs[r.rid]) == len(r.tokens) for r in reqs)

    summary = dict(
        engine.stats.serving_summary(),
        arch=arch,
        rate_rps=rate_rps,
        max_batch=max_batch,
    )
    print_fn("# serving — open-loop Poisson arrivals (continuous batching)")
    print_fn(
        f"arch={arch} requests={n_requests} rate={rate_rps}/s pool={max_batch}"
    )
    print_fn(
        f"ttft p50={summary['p50_ttft_ms']:.2f}ms p99={summary['p99_ttft_ms']:.2f}ms | "
        f"tpot p50={summary['p50_tpot_ms']:.2f}ms p99={summary['p99_tpot_ms']:.2f}ms | "
        f"step p50={summary['p50_step_ms']:.2f}ms p99={summary['p99_step_ms']:.2f}ms"
    )
    return summary


def run_shared_prefix(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 8,
    rate_rps: float = 50.0,
    max_batch: int = 4,
    prefix_len: int = 160,
    suffix_len: int = 32,
    tokens: int = 4,
    block_size: int = 16,
    seed: int = 0,
    print_fn=print,
) -> dict:
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.serve.load import make_shared_prefix_requests

    # mid-size so prefill compute (what reuse removes) beats dispatch noise
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        num_layers=4, d_model=128, d_ff=384, n_heads=4, n_kv_heads=2, head_dim=32,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    max_seq = prefix_len + suffix_len + tokens + block_size
    max_seq += (-max_seq) % block_size

    header = np.random.default_rng(seed).integers(
        0, cfg.vocab_size, size=(prefix_len,)
    ).astype(np.int32)

    def workload(rng):
        return make_shared_prefix_requests(
            n_requests, rate_rps, vocab=cfg.vocab_size, prefix_len=prefix_len,
            suffix_len=suffix_len, max_new_tokens=tokens, rng=rng, prefix=header,
        )

    results, outputs = {}, {}
    for reuse in (True, False):
        engine = ServingEngine(
            model, params, max_seq=max_seq, kv_layout="paged",
            block_size=block_size, prefix_cache=reuse,
        )
        sched = engine.scheduler(max_batch, seed=seed)
        # warm the jit caches AND (reuse on) the prefix trie: the warmup
        # workload shares the measured header but has different random
        # suffixes, so every measured request hits exactly the header
        # (same already-compiled suffix-prefill shape) — steady state,
        # no cold prefill and no compile inside the measured window
        sched.run(workload(np.random.default_rng(seed + 1)))
        reqs = workload(np.random.default_rng(seed))
        out = sched.run(reqs)
        sched.kv.check_invariants()
        key = "reuse_on" if reuse else "reuse_off"
        results[key] = engine.stats.serving_summary()
        outputs[key] = [np.asarray(out[r.rid]) for r in reqs]

    for a, b in zip(outputs["reuse_on"], outputs["reuse_off"]):
        np.testing.assert_array_equal(a, b)  # reuse must not change tokens
    hit_rate = results["reuse_on"]["prefix_hit_rate"]
    assert hit_rate > 0, "shared-prefix workload produced no prefix hits"
    assert results["reuse_off"]["prefix_hit_rate"] == 0

    summary = {
        "arch": arch,
        "prefix_len": prefix_len,
        "suffix_len": suffix_len,
        "block_size": block_size,
        "prefix_hit_rate": hit_rate,
        "reuse_on": results["reuse_on"],
        "reuse_off": results["reuse_off"],
        "ttft_p50_speedup": (
            results["reuse_off"]["p50_ttft_ms"] / results["reuse_on"]["p50_ttft_ms"]
            if results["reuse_on"]["p50_ttft_ms"]
            else 0.0
        ),
    }
    print_fn("# serving — shared-prefix reuse (paged KV cache)")
    print_fn(
        f"arch={arch} requests={n_requests} prompt={prefix_len}+{suffix_len} "
        f"block={block_size} hit_rate={hit_rate:.2f}"
    )
    for key in ("reuse_on", "reuse_off"):
        s = results[key]
        print_fn(
            f"{key:9s} ttft p50={s['p50_ttft_ms']:.2f}ms p99={s['p99_ttft_ms']:.2f}ms | "
            f"tpot p50={s['p50_tpot_ms']:.2f}ms"
        )
    print_fn(f"p50 TTFT speedup from reuse: {summary['ttft_p50_speedup']:.2f}x")
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix reuse mode (paged engine, on vs off)")
    args = ap.parse_args()
    if args.shared_prefix:
        run_shared_prefix()
    else:
        run()
