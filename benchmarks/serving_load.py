"""§Serving — open-loop Poisson-arrival load on the continuous-batching
serving core: p50/p99 TTFT and per-token decode latency.

Open-loop means arrivals follow their own (Poisson) schedule regardless
of completions — the honest way to load a latency-critical server,
since closed-loop drivers self-throttle and hide queueing delay. Each
request gets a random prompt length and token budget, so the run
exercises divergent per-slot cache lengths and slot reuse.

Feeds the ``serving`` section of ``BENCH_aira.json`` (benchmarks/run.py)
so serving latency is tracked across PRs. Request generation lives in
``repro.serve.load`` (shared with examples/serve_decode.py).

Usage: PYTHONPATH=src python -m benchmarks.serving_load
"""
from __future__ import annotations

import jax
import numpy as np


def run(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 12,
    rate_rps: float = 20.0,
    max_batch: int = 4,
    tokens: int = 8,
    seed: int = 0,
    print_fn=print,
) -> dict:
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.serve.load import make_requests

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    engine = ServingEngine(model, params, max_seq=64)
    rng = np.random.default_rng(seed)
    reqs = make_requests(
        n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens, rng=rng
    )
    outputs = engine.serve(reqs, max_batch=max_batch, seed=seed)
    assert all(r.finished for r in reqs)
    assert all(len(outputs[r.rid]) == len(r.tokens) for r in reqs)

    summary = dict(
        engine.stats.serving_summary(),
        arch=arch,
        rate_rps=rate_rps,
        max_batch=max_batch,
    )
    print_fn("# serving — open-loop Poisson arrivals (continuous batching)")
    print_fn(
        f"arch={arch} requests={n_requests} rate={rate_rps}/s pool={max_batch}"
    )
    print_fn(
        f"ttft p50={summary['p50_ttft_ms']:.2f}ms p99={summary['p99_ttft_ms']:.2f}ms | "
        f"tpot p50={summary['p50_tpot_ms']:.2f}ms p99={summary['p99_tpot_ms']:.2f}ms | "
        f"step p50={summary['p50_step_ms']:.2f}ms p99={summary['p99_step_ms']:.2f}ms"
    )
    return summary


if __name__ == "__main__":
    run()
