"""§Serving — open-loop Poisson-arrival load on the continuous-batching
serving core: p50/p99 TTFT and per-token decode latency.

Open-loop means arrivals follow their own (Poisson) schedule regardless
of completions — the honest way to load a latency-critical server,
since closed-loop drivers self-throttle and hide queueing delay. Each
request gets a random prompt length and token budget, so the run
exercises divergent per-slot cache lengths and slot reuse.

The speculative mode (``run_speculative`` / ``--speculative``) serves an
identical workload at K=0 and K ∈ {2,4,8} with the n-gram prompt-lookup
drafter on a mid-size config: it verifies token-identity against the
K=0 greedy baseline, asserts a nonzero acceptance rate (the CI smoke
contract), reports per-output-token latency at every depth, and runs
the ``SpeculationAdvisorTool`` on the measured profile so the advised
depth lands in the summary next to the measured best.

The shared-prefix mode (``run_shared_prefix`` / ``--shared-prefix``)
drives the paged engine with prompts sharing one long header (a system
prompt), once with prefix reuse on and once off, on an identical
workload: it reports the hit rate and p50/p99 TTFT both ways, verifies
the two runs decode token-identically, and asserts a nonzero hit rate
(the CI smoke contract). A mid-size config is used so prefill compute —
the cost reuse removes — dominates per-call dispatch overhead.

The backend mode (``run_backend_sweep`` / ``--backend``) serves an
identical open-loop workload on BOTH KV layouts through each attention
backend (DESIGN.md §4): it asserts token-identity against the
``reference`` backend per layout (the CI interpret-mode kernel smoke
contract — the real kernel code runs on CPU), reports p50 TPOT and
per-decode-step latency per (layout × backend), microbenches the
attention call itself (block-paged kernel vs the dense-gather
reference) at a serving-representative shape, and feeds the measured
per-step costs to the ``KernelAdvisorTool`` so the advised backend per
(family, layout, K) cell lands in the summary — measured, not assumed.

The SLO mode (``run_slo`` / ``--chunked [--overload]``) serves the
mixed interactive/batch workload (short high-priority prompts with a
long low-priority prompt every fourth arrival) once with monolithic
prefill and once chunked (``chunk_size`` prompt tokens per decode
step), on identical arrivals and a deliberately under-provisioned
paged pool, and reports SLO-attainment *goodput* — the fraction of
requests finishing within a TTFT/TPOT budget — for both. Monolithic
prefill stalls every co-resident decode for the full long-prompt
forward (and re-stalls on preemption-resume recompute, where its
prompt shapes also pay retraces the chunked trace family never
does — that tail is the measured phenomenon, not an artifact);
chunking bounds per-step work at ``chunk_size`` tokens, which is the
p99-step contract asserted here. The CI smoke contract: nonzero
preemptions under overload, nonzero goodput, and a strictly smaller
chunked p99 step.

The sharded mode (``run_sharded`` / ``--mesh N`` or ``--mesh NxM``)
serves one identical open-loop workload at every mesh shape through the
mesh-sharded paged path (DESIGN.md §5): head-only ``("model",)`` sizes
shard the paged pool's KV leaves head-wise, kv-sequence shapes
(``("seq",)`` and the 2D ``("model","seq")`` composition) partition the
pool's block dimension and recombine each softmax from per-rank flash
partials. Bitwise token identity against the single-device paged engine
is asserted for the head-only sizes, for plain, speculative (K=2), and
chunked-prefill serving — head partitioning moves parallel work, never
a reduction order — while the seq lanes assert argmax token identity
(the exact-combine tolerance contract); per-decode-step
latency is recorded per mesh size. When the process has fewer devices
than the largest mesh (the normal single-device CI run), the sweep
re-execs itself in a subprocess with a forced multi-device CPU host
platform, so ``benchmarks.run`` still lands ``serving.sharded`` in the
summary.

The drift mode (``run_drift`` / ``--drift``) is the online-adviser
proof (DESIGN.md §9): a phased workload whose draftability drifts
(repetitive → churn → shared-prefix), served once per static K arm and
once under the closed-loop ``OnlineAdviser`` (primed K × backend grid,
live re-decision from telemetry windows). Every static arm loses in
some phase; the controller must beat the worst static arm's p50 TPOT
and land within ``oracle_tolerance`` of the per-phase-best oracle,
with bitwise token identity across every arm, at least one live
switch, and ZERO retraces after ``prime()`` — pinned both by the
engine's jit-cache sizes and the ``engine.retraces`` counter. The
decision audit trail is written to ``--drift``'s JSON path (the CI
artifact).

The observability mode (``run_observability`` / ``--trace [PATH]``)
pins the flight-recorder contract (DESIGN.md §8): one warmed engine
serves an identical paged + speculative + chunked workload with
telemetry OFF and then ON (per-call ``telemetry=`` override, so both
runs share every jitted executable), asserts bitwise token identity
(recording is observation, never behaviour), asserts the instrumented
p50 decode step stays within a pinned factor of the uninstrumented
one, sanity-checks the ``window_summary`` adviser signal vector, runs
the ``SpeculationAdvisorTool`` on the measured profile so the decision
(with its priced inputs) lands in the trace as an adviser-audit event,
and — with ``--trace`` — exports Chrome/Perfetto trace-event JSON
(load in ui.perfetto.dev) validated by
``repro.serve.telemetry.validate_chrome_trace``.

Feeds the ``serving`` section of ``BENCH_aira.json`` (benchmarks/run.py)
so serving latency is tracked across PRs. Request generation lives in
``repro.serve.load`` (shared with examples/serve_decode.py).

Usage: PYTHONPATH=src python -m benchmarks.serving_load [--shared-prefix]
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


def run(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 12,
    rate_rps: float = 20.0,
    max_batch: int = 4,
    tokens: int = 8,
    seed: int = 0,
    kv_layout: str = "slot",
    backend: str = "auto",
    print_fn=print,
) -> dict:
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.serve.load import make_requests

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    engine = ServingEngine(
        model, params, max_seq=64, kv_layout=kv_layout, attention_backend=backend
    )
    rng = np.random.default_rng(seed)
    reqs = make_requests(
        n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens, rng=rng
    )
    outputs = engine.serve(reqs, max_batch=max_batch, seed=seed)
    assert all(r.finished for r in reqs)
    assert all(len(outputs[r.rid]) == len(r.tokens) for r in reqs)

    summary = dict(
        engine.stats.serving_summary(),
        arch=arch,
        rate_rps=rate_rps,
        max_batch=max_batch,
        kv_layout=kv_layout,
        # scalar key is `backend`: `attention_backend` is the sweep
        # section run.py records beside this summary
        backend=engine.attention_backend,
    )
    print_fn("# serving — open-loop Poisson arrivals (continuous batching)")
    print_fn(
        f"arch={arch} requests={n_requests} rate={rate_rps}/s pool={max_batch} "
        f"layout={kv_layout} backend={engine.attention_backend}"
    )
    print_fn(
        f"ttft p50={summary['p50_ttft_ms']:.2f}ms p99={summary['p99_ttft_ms']:.2f}ms | "
        f"tpot p50={summary['p50_tpot_ms']:.2f}ms p99={summary['p99_tpot_ms']:.2f}ms | "
        f"step p50={summary['p50_step_ms']:.2f}ms p99={summary['p99_step_ms']:.2f}ms"
    )
    return summary


def _attention_microbench(backends, reps: int = 20, seed: int = 0) -> dict:
    """Per-call attention-step wall-clock at a serving-representative
    paged shape: the block-table-walking kernel vs the dense-gather
    reference, isolated from the rest of the decode step. Returns
    backend → µs/call."""
    import time

    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    B, T, KV, g, hd, NB, BS, MB = 4, 1, 2, 2, 32, 64, 16, 8
    q = jnp.asarray(rng.normal(size=(B, T, KV * g, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, BS, KV, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, BS, KV, hd)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, NB, size=(B, MB)), jnp.int32)
    lens = jnp.asarray(rng.integers(BS, MB * BS - 1, size=(B,)), jnp.int32)
    out = {}
    for backend in backends:
        f = lambda: ops.paged_attention(q, kp, vp, tbl, lens, mode=backend)
        jax.block_until_ready(f())  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f())
        out[backend] = (time.perf_counter() - t0) / reps * 1e6
    return out


def run_backend_sweep(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 6,
    rate_rps: float = 50.0,
    max_batch: int = 3,
    tokens: int = 8,
    backends=("reference", "interpret"),
    seed: int = 0,
    print_fn=print,
) -> dict:
    """Identical open-loop workload on both KV layouts through each
    attention backend: token-identity vs ``reference`` asserted per
    layout (the CI kernel-smoke contract — interpret mode runs the real
    block-paged kernel code on CPU), p50 TPOT / per-step latency
    recorded per (layout × backend), and the measured per-step costs
    fed to the ``KernelAdvisorTool`` for the advised backend per cell."""
    from repro.configs import get_config
    from repro.core.tools import KernelAdvisorTool, KernelMeasurement
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.serve.load import make_requests

    # reference leads (it is the identity baseline); dedupe so
    # --backend reference degrades to a plain reference run, not a
    # vacuous self-comparison
    backends = tuple(dict.fromkeys(("reference",) + tuple(backends)))
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    engine = ServingEngine(model, params, max_seq=64, block_size=8)

    def workload():
        return make_requests(
            n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens,
            rng=np.random.default_rng(seed),
        )

    results: dict = {}
    for layout in ("slot", "paged"):
        results[layout] = {}
        outputs = {}
        for backend in backends:
            reqs = workload()  # warm the jit cache outside the window
            engine.serve(reqs, max_batch=max_batch, seed=seed,
                         kv_layout=layout, attention_backend=backend)
            reqs = workload()
            out = engine.serve(reqs, max_batch=max_batch, seed=seed,
                               kv_layout=layout, attention_backend=backend)
            outputs[backend] = [np.asarray(out[r.rid]) for r in reqs]
            s = engine.stats.serving_summary()
            results[layout][backend] = {
                "p50_tpot_ms": s["p50_tpot_ms"],
                "p50_step_ms": s["p50_step_ms"],
                "p99_step_ms": s["p99_step_ms"],
            }
        for backend in backends[1:]:
            for a, b in zip(outputs["reference"], outputs[backend]):
                np.testing.assert_array_equal(
                    a, b,
                    err_msg=f"{layout}/{backend} diverged from the reference backend",
                )

    attn_us = _attention_microbench(backends, seed=seed)

    # the advisory gate prices the measured per-step cost per cell —
    # the engine honors the decision via serve(attention_backend=...)
    tool = KernelAdvisorTool()
    advised, advisor_log = {}, []
    for layout in ("slot", "paged"):
        m = KernelMeasurement.make(
            cfg.family, layout, 0,
            {b: results[layout][b]["p50_step_ms"] for b in backends},
        )
        choice, gain, log = tool.choose(m)
        advised[layout] = choice
        advisor_log.append(log)

    summary = {
        "arch": arch,
        "backends": list(backends),
        "slot": results["slot"],
        "paged": results["paged"],
        "attn_us": attn_us,
        "advised": advised,
    }
    print_fn("# serving — attention-backend sweep (token-identity asserted)")
    print_fn(f"arch={arch} requests={n_requests} tokens={tokens} pool={max_batch}")
    for layout in ("slot", "paged"):
        for backend in backends:
            r = results[layout][backend]
            print_fn(
                f"{layout:5s}/{backend:9s} tpot p50={r['p50_tpot_ms']:.2f}ms "
                f"step p50={r['p50_step_ms']:.2f}ms"
            )
    print_fn(
        "attention µbench: "
        + " ".join(f"{b}={us:.0f}µs" for b, us in attn_us.items())
    )
    for line in advisor_log:
        print_fn(f"advisor: {line}")
    return summary


def run_shared_prefix(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 8,
    rate_rps: float = 50.0,
    max_batch: int = 4,
    prefix_len: int = 160,
    suffix_len: int = 32,
    tokens: int = 4,
    block_size: int = 16,
    seed: int = 0,
    print_fn=print,
) -> dict:
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.serve.load import make_shared_prefix_requests

    # mid-size so prefill compute (what reuse removes) beats dispatch noise
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        num_layers=4, d_model=128, d_ff=384, n_heads=4, n_kv_heads=2, head_dim=32,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    max_seq = prefix_len + suffix_len + tokens + block_size
    max_seq += (-max_seq) % block_size

    header = np.random.default_rng(seed).integers(
        0, cfg.vocab_size, size=(prefix_len,)
    ).astype(np.int32)

    def workload(rng):
        return make_shared_prefix_requests(
            n_requests, rate_rps, vocab=cfg.vocab_size, prefix_len=prefix_len,
            suffix_len=suffix_len, max_new_tokens=tokens, rng=rng, prefix=header,
        )

    results, outputs = {}, {}
    for reuse in (True, False):
        engine = ServingEngine(
            model, params, max_seq=max_seq, kv_layout="paged",
            block_size=block_size, prefix_cache=reuse,
        )
        sched = engine.scheduler(max_batch, seed=seed)
        # warm the jit caches AND (reuse on) the prefix trie: the warmup
        # workload shares the measured header but has different random
        # suffixes, so every measured request hits exactly the header
        # (same already-compiled suffix-prefill shape) — steady state,
        # no cold prefill and no compile inside the measured window
        sched.run(workload(np.random.default_rng(seed + 1)))
        reqs = workload(np.random.default_rng(seed))
        out = sched.run(reqs)
        sched.kv.check_invariants()
        key = "reuse_on" if reuse else "reuse_off"
        results[key] = engine.stats.serving_summary()
        outputs[key] = [np.asarray(out[r.rid]) for r in reqs]

    for a, b in zip(outputs["reuse_on"], outputs["reuse_off"]):
        np.testing.assert_array_equal(a, b)  # reuse must not change tokens
    hit_rate = results["reuse_on"]["prefix_hit_rate"]
    assert hit_rate > 0, "shared-prefix workload produced no prefix hits"
    assert results["reuse_off"]["prefix_hit_rate"] == 0

    summary = {
        "arch": arch,
        "prefix_len": prefix_len,
        "suffix_len": suffix_len,
        "block_size": block_size,
        "prefix_hit_rate": hit_rate,
        "reuse_on": results["reuse_on"],
        "reuse_off": results["reuse_off"],
        "ttft_p50_speedup": (
            results["reuse_off"]["p50_ttft_ms"] / results["reuse_on"]["p50_ttft_ms"]
            if results["reuse_on"]["p50_ttft_ms"]
            else 0.0
        ),
    }
    print_fn("# serving — shared-prefix reuse (paged KV cache)")
    print_fn(
        f"arch={arch} requests={n_requests} prompt={prefix_len}+{suffix_len} "
        f"block={block_size} hit_rate={hit_rate:.2f}"
    )
    for key in ("reuse_on", "reuse_off"):
        s = results[key]
        print_fn(
            f"{key:9s} ttft p50={s['p50_ttft_ms']:.2f}ms p99={s['p99_ttft_ms']:.2f}ms | "
            f"tpot p50={s['p50_tpot_ms']:.2f}ms"
        )
    print_fn(f"p50 TTFT speedup from reuse: {summary['ttft_p50_speedup']:.2f}x")
    return summary


def run_speculative(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 6,
    rate_rps: float = 50.0,
    max_batch: int = 3,
    prompt_len: int = 12,
    tokens: int = 24,
    ks=(2, 4, 8),
    seed: int = 0,
    print_fn=print,
) -> dict:
    from repro.configs import get_config
    from repro.core.tools import SpecMeasurement, SpeculationAdvisorTool
    from repro.models import Model
    from repro.serve import ServingEngine, SpecConfig
    from repro.serve.load import make_requests

    # mid-size so a saved decode step (what acceptance removes) is real
    # compute, not dispatch noise — same sizing as the shared-prefix mode
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        num_layers=4, d_model=128, d_ff=384, n_heads=4, n_kv_heads=2, head_dim=32,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    engine = ServingEngine(model, params, max_seq=prompt_len + tokens + max(ks) + 8)

    def workload(rng_seed=seed):
        # long budgets on short prompts: tiny greedy models settle into
        # repetitive continuations, exactly what prompt-lookup drafts
        return make_requests(
            n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens,
            prompt_lens=(prompt_len,), rng=np.random.default_rng(rng_seed),
        )

    results, outputs = {}, {}
    for k in (0,) + tuple(ks):
        reqs = workload()
        spec = SpecConfig(k=k, drafter="ngram")
        engine.serve(reqs, max_batch=max_batch, seed=seed, spec=spec)  # warm jit
        reqs = workload()
        out = engine.serve(reqs, max_batch=max_batch, seed=seed, spec=spec)
        results[k] = engine.stats.serving_summary()
        outputs[k] = [np.asarray(out[r.rid]) for r in reqs]

    for k in ks:
        for a, b in zip(outputs[0], outputs[k]):
            np.testing.assert_array_equal(a, b)  # speculation must not change tokens
        assert results[k]["speculative"]["acceptance_rate"] > 0, (
            f"K={k}: no draft token survived the verify"
        )

    # feed the measured profile to the advisory gate (probe = deepest K)
    kmax = max(ks)
    s = results[kmax]["speculative"]
    meas = SpecMeasurement(
        draft_ms_per_token=s["p50_draft_ms"] / kmax,
        verify_ms={0: results[0]["p50_step_ms"], kmax: s["p50_verify_ms"]},
        acceptance_rate=s["acceptance_rate"],
    )
    advised_k, gain, log = SpeculationAdvisorTool(ks=(0,) + tuple(ks)).choose(meas)

    best_k = min(ks, key=lambda k: results[k]["p50_tpot_ms"])
    summary = {
        "arch": arch,
        "drafter": "ngram",
        "baseline": results[0],
        **{f"k{k}": results[k] for k in ks},
        "advised_k": advised_k,
        "advised_gain": gain,
        "best_k": best_k,
        "tpot_p50_speedup": (
            results[0]["p50_tpot_ms"] / results[best_k]["p50_tpot_ms"]
            if results[best_k]["p50_tpot_ms"]
            else 0.0
        ),
    }
    print_fn("# serving — speculative decode (n-gram drafter, K=0 baseline)")
    print_fn(f"arch={arch} requests={n_requests} prompt={prompt_len} tokens={tokens}")
    for k in (0,) + tuple(ks):
        s = results[k]
        extra = (
            f" accept={s['speculative']['acceptance_rate']:.2f}"
            if k else " (plain greedy)"
        )
        print_fn(
            f"K={k}: tpot p50={s['p50_tpot_ms']:.2f}ms "
            f"step p50={s['p50_step_ms']:.2f}ms{extra}"
        )
    print_fn(f"advisor: {log}")
    print_fn(
        f"best K={best_k}: {summary['tpot_p50_speedup']:.2f}x per-token speedup vs K=0"
    )
    # token-identity and nonzero acceptance above are deterministic
    # contracts; the latency comparison is wall-clock and can wobble on
    # a noisy shared runner, so it is reported exactly but asserted
    # with slack — a genuine regression (speculation slower than plain
    # greedy) still trips it
    assert summary["tpot_p50_speedup"] > 0.9, (
        f"speculation made per-output-token latency materially worse "
        f"({summary['tpot_p50_speedup']:.2f}x vs the K=0 baseline)"
    )
    return summary


def _jit_cache_size(engine) -> int:
    """Total compile-cache entries across the engine's shared jitted
    step fns — the drift benchmark's no-retrace witness: any mid-serve
    K/backend switch that escaped the primed trace families grows it."""
    fns = [engine._prefill, engine._prefill_prefix]
    for family in engine._steps.values():
        fns.extend(family.values())
    return sum(
        f._cache_size() for f in fns if f is not None and hasattr(f, "_cache_size")
    )


def run_drift(
    *,
    arch: str = "smollm-135m",
    max_batch: int = 3,
    rate_rps: float = 60.0,
    ks=(0, 2, 4),
    phase_n=(8, 10, 8),
    rep_tokens: int = 24,
    churn_tokens: int = 4,
    churn_prompt_lens=(24, 32, 40),
    prefix_len: int = 16,
    decision_interval: int = 4,
    window: int = 12,
    oracle_tolerance: float = 1.6,
    decisions_path=None,
    seed: int = 0,
    print_fn=print,
) -> dict:
    import json

    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import OnlineAdviser, ServingEngine, SpecConfig, Telemetry
    from repro.serve.load import make_drift_requests

    # mid-size (run_speculative sizing): a saved decode step must be
    # real compute, or no arm separates from any other
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        num_layers=4, d_model=128, d_ff=384, n_heads=4, n_kv_heads=2, head_dim=32,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    engine = ServingEngine(model, params, max_seq=64, kv_layout="paged", block_size=8)
    ks = tuple(sorted({int(k) for k in ks}))
    kmax = max(ks)
    # one shared header across every draw, so warmup and measured runs
    # hit the same prefix-cache entries
    prefix = (
        np.random.default_rng(seed + 7)
        .integers(0, cfg.vocab_size, size=(prefix_len,))
        .astype(np.int32)
    )

    def workload(rng_seed=seed):
        # churn: long random prompts (an expensive n-gram scan per draft
        # round, nothing draftable in them) with tiny budgets — every
        # speculated token is pure overhead there
        return make_drift_requests(
            phase_n, rate_rps, vocab=cfg.vocab_size, rep_tokens=rep_tokens,
            churn_tokens=churn_tokens, churn_prompt_lens=churn_prompt_lens,
            prefix_len=prefix_len,
            rng=np.random.default_rng(rng_seed), prefix=prefix,
        )

    def spec_for(k):
        return SpecConfig(k=k, drafter="ngram") if k else SpecConfig(k=0)

    # prime the K × backend grid (decode + every verify width), then warm
    # each arm's full trace family on the real workload shapes (prefill
    # buckets, prefix path); everything after this must be a cache hit
    primed = engine.prime(max_batch, ks=ks)
    for k in ks:
        reqs, _ = workload()
        engine.serve(reqs, max_batch=max_batch, seed=seed, spec=spec_for(k))
    cache_warm = _jit_cache_size(engine)

    def _tpots(rs):
        return [r.tpot_ms for r in rs if r.tpot_ms is not None]

    def _p50(vals):
        return float(np.percentile(vals, 50)) if vals else 0.0

    # measured static arms: identical workload per arm, per-phase TPOT.
    # Every measured run — static and controlled — serves through the
    # same enabled flight recorder: the controller NEEDS the windowed
    # metrics, so the static arms pay the identical instrumented cost
    # (policies are compared, not telemetry overhead)
    tel = Telemetry(enabled=True, capacity=8192)
    arm_tpots, phase_tpots, outputs = {}, {}, {}
    spans = None
    for k in ks:
        reqs, spans = workload()
        out = engine.serve(
            reqs, max_batch=max_batch, seed=seed, spec=spec_for(k), telemetry=tel
        )
        outputs[k] = [np.asarray(out[r.rid]) for r in reqs]
        arm_tpots[k] = _tpots(reqs)
        phase_tpots[k] = {name: _tpots(reqs[s:e]) for name, s, e in spans}

    # the controller run: deepest arm's margin + drafter, live depth
    # re-decided every decision_interval steps from the telemetry window
    ctl = OnlineAdviser(
        ks=ks, decision_interval=decision_interval, window=window,
        dwell=1, threshold=0.03, probe_every=2,
    )
    ctl.seed_costs(primed)
    reqs, spans = workload()
    out = engine.serve(
        reqs, max_batch=max_batch, seed=seed,
        spec=SpecConfig(k=kmax, drafter="ngram"), controller=ctl, telemetry=tel,
    )
    ctl_outputs = [np.asarray(out[r.rid]) for r in reqs]
    ctl_tpots = _tpots(reqs)
    ctl_phase = {name: _tpots(reqs[s:e]) for name, s, e in spans}
    cache_end = _jit_cache_size(engine)
    retraces = engine.stats.registry.counter("engine.retraces").value

    # deterministic contracts first: greedy streams are invariant under
    # speculation depth AND under live re-decision of it
    for k in ks[1:]:
        for a, b in zip(outputs[ks[0]], outputs[k]):
            np.testing.assert_array_equal(a, b)
    for a, b in zip(outputs[ks[0]], ctl_outputs):
        np.testing.assert_array_equal(a, b)
    assert len(ctl.decisions) > 0, "controller never reached a decision interval"
    assert ctl.n_switches >= 1, (
        f"controller never switched arms across a drifting workload "
        f"(decisions={len(ctl.decisions)})"
    )
    assert cache_end == cache_warm, (
        f"live switching retraced: jit cache grew {cache_warm} → {cache_end} "
        f"after prime+warmup"
    )
    assert retraces == 0, f"engine.retraces counter saw {retraces} mid-run compiles"

    # the latency contract: controller beats the worst static arm and
    # tracks the per-phase-best oracle (tpot pooled from each phase's
    # winning arm) within tolerance
    arm_p50 = {k: _p50(arm_tpots[k]) for k in ks}
    worst_k = max(ks, key=lambda k: arm_p50[k])
    best_static_k = min(ks, key=lambda k: arm_p50[k])
    phase_best, oracle_pool = {}, []
    for name, _, _ in spans:
        bk = min(ks, key=lambda k: _p50(phase_tpots[k][name]))
        phase_best[name] = bk
        oracle_pool.extend(phase_tpots[bk][name])
    oracle_p50 = _p50(oracle_pool)
    ctl_p50 = _p50(ctl_tpots)

    summary = {
        "arch": arch,
        "ks": list(ks),
        "phases": [
            {
                "name": name,
                "n": e - s,
                "best_k": phase_best[name],
                **{f"k{k}_p50_tpot_ms": _p50(phase_tpots[k][name]) for k in ks},
                "controller_p50_tpot_ms": _p50(ctl_phase[name]),
            }
            for name, s, e in spans
        ],
        **{f"k{k}_p50_tpot_ms": arm_p50[k] for k in ks},
        "worst_static_k": worst_k,
        "best_static_k": best_static_k,
        "oracle_p50_tpot_ms": oracle_p50,
        "controller_p50_tpot_ms": ctl_p50,
        "controller_vs_worst": arm_p50[worst_k] / ctl_p50 if ctl_p50 else 0.0,
        "controller_vs_oracle": ctl_p50 / oracle_p50 if oracle_p50 else 0.0,
        "decisions": len(ctl.decisions),
        "switches": ctl.n_switches,
        "retraces_after_prime": int(cache_end - cache_warm),
        "controller": ctl.summary(),
    }

    print_fn("# serving — drift workload (online adviser vs static K arms)")
    print_fn(
        f"arch={arch} phases={[n for n, _, _ in spans]} "
        f"requests={sum(int(n) for n in phase_n)} ks={list(ks)}"
    )
    for ph in summary["phases"]:
        cells = " ".join(f"K={k}:{ph[f'k{k}_p50_tpot_ms']:.2f}ms" for k in ks)
        print_fn(
            f"{ph['name']:>13}: {cells} ctl:{ph['controller_p50_tpot_ms']:.2f}ms "
            f"(best K={ph['best_k']})"
        )
    print_fn(
        f"overall p50 tpot: "
        + " ".join(f"K={k}:{arm_p50[k]:.2f}ms" for k in ks)
        + f" oracle:{oracle_p50:.2f}ms controller:{ctl_p50:.2f}ms"
    )
    print_fn(
        f"controller: {len(ctl.decisions)} decisions, {ctl.n_switches} switches, "
        f"{int(cache_end - cache_warm)} retraces after prime"
    )
    for d in ctl.audit_trail():
        print_fn(
            f"  step {d['step']:>3}: k={d['k']} backend={d['backend']}"
            + (" [probe]" if d["probe"] else "")
            + (f" gain={d['predicted_gain']:+.1%}" if d["switched"] else "")
            + f" — {d['reason']}"
        )
    if decisions_path:
        with open(decisions_path, "w") as f:
            json.dump(
                {"decisions": ctl.audit_trail(), "controller": ctl.summary(),
                 "summary": {k: v for k, v in summary.items() if k != "phases"}},
                f, indent=2, default=str,
            )
        print_fn(f"decision audit trail → {decisions_path}")

    assert ctl_p50 < arm_p50[worst_k], (
        f"controller p50 TPOT {ctl_p50:.2f}ms did not beat the worst static "
        f"arm K={worst_k} ({arm_p50[worst_k]:.2f}ms)"
    )
    assert ctl_p50 <= oracle_p50 * oracle_tolerance, (
        f"controller p50 TPOT {ctl_p50:.2f}ms outside {oracle_tolerance}x of "
        f"the per-phase-best oracle ({oracle_p50:.2f}ms)"
    )
    return summary


def _run_sharded_subprocess(kwargs: dict, need: int, print_fn) -> dict:
    """Re-exec ``run_sharded`` with a forced ``need``-device CPU host
    platform. XLA_FLAGS must be set before jax initializes, and this
    process has already initialized it with its real (single) device —
    so the sweep itself runs in a child and ships its summary back as a
    sentinel-prefixed JSON line."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={need}"
    ).strip()
    code = (
        "import json\n"
        "from benchmarks import serving_load\n"
        f"s = serving_load.run_sharded(**{kwargs!r})\n"
        "print('SHARDED_JSON::' + json.dumps(s))\n"
    )
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=1200,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded sweep subprocess failed:\n{r.stderr}\n{r.stdout}"
        )
    summary = None
    for line in r.stdout.splitlines():
        if line.startswith("SHARDED_JSON::"):
            summary = json.loads(line[len("SHARDED_JSON::"):])
        else:
            print_fn(line)
    assert summary is not None, r.stdout
    return summary


def run_sharded(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 8,
    rate_rps: float = 50.0,
    max_batch: int = 4,
    tokens: int = 8,
    mesh_sizes=(1, 2, 4),
    seq_shapes=((2,), (2, 2)),
    backend: str = "interpret",
    seed: int = 0,
    print_fn=print,
) -> dict:
    """One workload, every mesh shape: the mesh-sharded paged serving
    path (DESIGN.md §5) vs the single-device paged engine. Two lanes:

    * ``mesh_sizes`` — head-only ``("model",)`` meshes. Per size the
      same open-loop arrivals are served plain, speculative (K=2,
      n-gram drafter), and with chunked prefill; all three streams are
      asserted BITWISE identical to the mesh-less run (head
      partitioning + all-gather preserves every reduction order).
    * ``seq_shapes`` — kv-sequence-split shapes: ``(sp,)`` serves over
      a pure ``("seq",)`` mesh, ``(tp, sp)`` over the 2D
      ``("model", "seq")`` composition. These recombine each softmax
      from per-rank flash partials (``distributed_softmax``), so the
      lane's contract is the tolerance one: argmax token identity
      (greedy streams match exactly) rather than bitwise logits. The
      per-shape step latency lands under the summary's ``"seq"`` key
      (→ ``serving.sharded.seq`` in BENCH).

    Per-decode-step latency is recorded from each plain serve. The
    default ``interpret`` backend runs the real block-paged kernel code
    per-shard on CPU (the CI smoke contract). Latency across forced CPU
    host-platform "devices" shares the same cores, so the numbers track
    dispatch/collective overhead, not speedup — the contract asserted
    here is identity, the latency is reported."""
    seq_shapes = tuple(tuple(s) for s in seq_shapes)
    need = max(
        max(mesh_sizes),
        max((int(np.prod(s)) for s in seq_shapes), default=1),
    )
    if need > 1 and len(jax.devices()) < need:
        return _run_sharded_subprocess(
            dict(arch=arch, n_requests=n_requests, rate_rps=rate_rps,
                 max_batch=max_batch, tokens=tokens,
                 mesh_sizes=tuple(mesh_sizes), seq_shapes=seq_shapes,
                 backend=backend, seed=seed),
            need, print_fn,
        )

    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine, SpecConfig
    from repro.serve.load import make_requests

    # mid-size with 8 query / 4 kv heads so every mesh size in the sweep
    # divides both (g=2 exercises GQA grouping under the head split)
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        num_layers=4, d_model=128, d_ff=384, n_heads=8, n_kv_heads=4, head_dim=16,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))

    def workload():
        return make_requests(
            n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens,
            rng=np.random.default_rng(seed),
        )

    modes = (
        ("plain", {}),
        ("speculative", {"spec": SpecConfig(k=2, drafter="ngram")}),
        ("chunked", {"chunk_size": 4}),
    )
    results, outputs, base_raw = {}, {}, {}
    for tp in mesh_sizes:
        if tp > 1:
            try:
                mesh = jax.make_mesh(
                    (tp,), ("model",), axis_types=(jax.sharding.AxisType.Auto,)
                )
            except AttributeError:  # jax 0.4.x: no AxisType
                mesh = jax.make_mesh((tp,), ("model",))
        else:
            mesh = None
        engine = ServingEngine(
            model, params, max_seq=64, kv_layout="paged", mesh=mesh,
            attention_backend=backend,
        )
        if tp > 1:
            assert engine.mesh is mesh, "sharded sweep fell back to replicated"
        outputs[tp] = {}
        for mode, kw in modes:
            engine.serve(workload(), max_batch=max_batch, seed=seed, **kw)  # warm
            reqs = workload()
            out = engine.serve(reqs, max_batch=max_batch, seed=seed, **kw)
            outputs[tp][mode] = [np.asarray(out[r.rid]) for r in reqs]
            if tp == mesh_sizes[0]:
                base_raw[mode] = out
            if mode == "plain":
                s = engine.stats.serving_summary()
                results[f"tp{tp}"] = {
                    "p50_step_ms": s["p50_step_ms"],
                    "p99_step_ms": s["p99_step_ms"],
                    "p50_tpot_ms": s["p50_tpot_ms"],
                }

    base_tp = mesh_sizes[0]
    for tp in mesh_sizes[1:]:
        for mode, _ in modes:
            for a, b in zip(outputs[base_tp][mode], outputs[tp][mode]):
                np.testing.assert_array_equal(
                    a, b,
                    err_msg=f"mesh={tp} {mode} diverged from the "
                            f"single-device paged path",
                )

    # kv-sequence-split lane: pure ("seq",) and 2D ("model","seq")
    # shapes, tolerance contract — argmax token identity via the shared
    # serve-level differential (repro.serve.differential)
    from repro.serve.differential import assert_streams_equal

    seq_results = {}
    for shape in seq_shapes:
        names = ("seq",) if len(shape) == 1 else ("model", "seq")
        key = "x".join(f"{n}{s}" for n, s in zip(names, shape))
        try:
            mesh = jax.make_mesh(
                shape, names, axis_types=(jax.sharding.AxisType.Auto,) * len(shape)
            )
        except AttributeError:  # jax 0.4.x: no AxisType
            mesh = jax.make_mesh(shape, names)
        engine = ServingEngine(
            model, params, max_seq=64, kv_layout="paged", mesh=mesh,
            attention_backend=backend,
        )
        assert engine.mesh is mesh, "seq-split sweep fell back to replicated"
        for mode, kw in modes:
            engine.serve(workload(), max_batch=max_batch, seed=seed, **kw)  # warm
            out = engine.serve(workload(), max_batch=max_batch, seed=seed, **kw)
            assert_streams_equal(
                base_raw[mode], out, label=f"mesh={key} {mode}"
            )
            if mode == "plain":
                s = engine.stats.serving_summary()
                seq_results[key] = {
                    "p50_step_ms": s["p50_step_ms"],
                    "p99_step_ms": s["p99_step_ms"],
                    "p50_tpot_ms": s["p50_tpot_ms"],
                }

    summary = {
        "arch": arch,
        "mesh_sizes": list(mesh_sizes),
        "backend": backend,
        "identity": "bitwise (plain, speculative K=2, chunked)",
        **results,
    }
    if seq_results:
        summary["seq"] = {
            "shapes": ["x".join(map(str, s)) for s in seq_shapes],
            "identity": "argmax token identity (tolerance lane, "
                        "exact flash-partials combine)",
            **seq_results,
        }
    print_fn("# serving — mesh-sharded paged decode (token-identity asserted)")
    print_fn(
        f"arch={arch} requests={n_requests} tokens={tokens} pool={max_batch} "
        f"heads={cfg.n_heads}/{cfg.n_kv_heads} backend={backend} "
        f"mesh_sizes={list(mesh_sizes)} seq_shapes={list(seq_shapes)}"
    )
    for tp in mesh_sizes:
        r = results[f"tp{tp}"]
        print_fn(
            f"mesh={tp}: step p50={r['p50_step_ms']:.2f}ms "
            f"p99={r['p99_step_ms']:.2f}ms tpot p50={r['p50_tpot_ms']:.2f}ms"
        )
    for key, r in seq_results.items():
        print_fn(
            f"mesh={key}: step p50={r['p50_step_ms']:.2f}ms "
            f"p99={r['p99_step_ms']:.2f}ms tpot p50={r['p50_tpot_ms']:.2f}ms"
        )
    print_fn("token identity: plain + speculative(K=2) + chunked — "
             "bitwise (model), argmax tokens (seq lanes)")
    return summary


def run_observability(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 8,
    rate_rps: float = 50.0,
    max_batch: int = 3,
    prompt_len: int = 12,
    tokens: int = 12,
    chunk_size: int = 8,
    spec_k: int = 2,
    reps: int = 3,
    overhead_factor: float = 1.5,
    trace_path: str | None = None,
    seed: int = 0,
    print_fn=print,
) -> dict:
    """Flight-recorder contract: tracing observes, never perturbs.

    One warmed paged engine serves the same speculative + chunked
    open-loop workload with telemetry off and on — the per-call
    ``telemetry=`` override means both runs share every jitted
    executable, so the measured delta is pure recording overhead. The
    off/on serves are interleaved ``reps`` times and compared as
    PAIRED per-rep p50 ratios (machine drift moves both sides of a
    pair together, so the best pair isolates the recording cost from
    shared-runner noise); the pinned ``overhead_factor`` is the BENCH
    guard against gross regressions like an accidental per-event host
    sync. Token identity off == on is
    asserted bitwise. The ON run's ``window_summary`` (the online-
    adviser signal vector) is sanity-checked, and the measured
    speculation profile is fed to ``SpeculationAdvisorTool`` while the
    recorder is armed so the decision — with its priced inputs — lands
    in the exported trace as an adviser-audit event. ``trace_path``
    exports Chrome/Perfetto JSON, validated structurally before the
    path is reported."""
    from repro.configs import get_config
    from repro.core.tools import SpecMeasurement, SpeculationAdvisorTool
    from repro.models import Model
    from repro.serve import ServingEngine, SpecConfig
    from repro.serve.load import make_requests
    from repro.serve.telemetry import Telemetry, validate_chrome_trace

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    engine = ServingEngine(
        model, params, max_seq=64, kv_layout="paged", block_size=8
    )
    spec = SpecConfig(k=spec_k, drafter="ngram")
    serve_kw = dict(max_batch=max_batch, seed=seed, spec=spec, chunk_size=chunk_size)

    def workload():
        return make_requests(
            n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens,
            prompt_lens=(prompt_len,), rng=np.random.default_rng(seed),
        )

    tel = Telemetry(enabled=True, capacity=1 << 16)
    off = Telemetry(enabled=False)
    engine.serve(workload(), **serve_kw)  # warm every jitted executable

    p50 = {"off": [], "on": []}
    outputs: dict = {}
    for _ in range(reps):
        for mode, t in (("off", off), ("on", tel)):
            reqs = workload()
            out = engine.serve(reqs, telemetry=t, **serve_kw)
            p50[mode].append(engine.stats.percentile(50))
            outputs[mode] = [np.asarray(out[r.rid]) for r in reqs]
    for a, b in zip(outputs["off"], outputs["on"]):
        np.testing.assert_array_equal(
            a, b, err_msg="telemetry changed the decoded tokens"
        )

    # the ON run left its windows on the shared stats registry
    window = engine.stats.registry.window_summary(8)
    assert window["admitted"] > 0, "no admissions landed in the window"
    assert window["step_cost_ms"] > 0, "no step cost landed in the window"
    assert 0.0 <= window["acceptance_rate"] <= 1.0
    assert window["pool_occupancy"] >= 0.0

    # adviser audit: price the measured profile with the recorder armed
    s = engine.stats.serving_summary()["speculative"]
    meas = SpecMeasurement(
        draft_ms_per_token=s["p50_draft_ms"] / max(1, spec_k),
        verify_ms={0: s["p50_verify_ms"], spec_k: s["p50_verify_ms"]},
        acceptance_rate=s["acceptance_rate"],
    )
    import repro.serve.telemetry as telemetry_mod

    was = telemetry_mod.GLOBAL
    telemetry_mod.GLOBAL = tel  # tools read the module global
    try:
        advised_k, _gain, advisor_line = SpeculationAdvisorTool(
            ks=(0, spec_k)
        ).choose(meas)
    finally:
        telemetry_mod.GLOBAL = was

    names = {e[1] for e in tel.tracer.events}
    assert "step" in names, "no scheduler step span recorded"
    assert "speculation-decision" in names, "advisor decision not in trace"
    counts = validate_chrome_trace(tel.tracer.to_chrome_trace())
    if trace_path:
        tel.tracer.export(trace_path)

    p50_off, p50_on = min(p50["off"]), min(p50["on"])
    ratio = min(
        (on / off) for off, on in zip(p50["off"], p50["on"]) if off
    )
    summary = {
        "arch": arch,
        "n_requests": n_requests,
        "spec_k": spec_k,
        "chunk_size": chunk_size,
        "p50_step_off_ms": p50_off,
        "p50_step_on_ms": p50_on,
        "overhead_ratio": ratio,
        "max_overhead_factor": overhead_factor,
        "trace_events": len(tel.tracer.events),
        "trace_counts": counts,
        "advised_k": advised_k,
        "window": window,
    }
    if trace_path:
        summary["trace_path"] = trace_path
    print_fn("# serving — flight recorder (token-identity + overhead guard)")
    print_fn(
        f"arch={arch} requests={n_requests} K={spec_k} chunk={chunk_size} "
        f"pool={max_batch} reps={reps}"
    )
    print_fn(
        f"step p50 off={p50_off:.3f}ms on={p50_on:.3f}ms "
        f"overhead={ratio:.2f}x (pinned <{overhead_factor}x)"
    )
    print_fn(
        f"trace: {counts['events']} events ({counts['spans']} spans, "
        f"{counts['async_spans']} request spans, {counts['instants']} instants)"
        + (f" → {trace_path}" if trace_path else "")
    )
    print_fn(
        "window(8): "
        f"accept={window['acceptance_rate']:.2f} queue={window['queue_depth']:.1f} "
        f"occ={window['pool_occupancy']:.2f} step={window['step_cost_ms']:.3f}ms"
    )
    print_fn(f"advisor: {advisor_line}")
    assert ratio < overhead_factor, (
        f"telemetry overhead {ratio:.2f}x exceeds the pinned "
        f"{overhead_factor}x budget"
    )
    return summary


def _goodput(reqs, ttft_slo_ms: float, tpot_slo_ms) -> float:
    """Fraction of requests that finished AND met the latency SLO:
    TTFT (queueing included — the user-visible number) within
    ``ttft_slo_ms``, and, when ``tpot_slo_ms`` is set and the request
    decoded more than one token, per-token latency within it."""
    ok = 0
    for r in reqs:
        good = r.finished and r.ttft_ms is not None and r.ttft_ms <= ttft_slo_ms
        if good and tpot_slo_ms is not None and r.tpot_ms is not None:
            good = r.tpot_ms <= tpot_slo_ms
        ok += bool(good)
    return ok / len(reqs) if reqs else 0.0


def run_slo(
    *,
    arch: str = "smollm-135m",
    n_requests: int = 20,
    rate_rps: float = 60.0,
    max_batch: int = 4,
    tokens: int = 8,
    chunk_size: int = 16,
    long_len: int = 192,
    short_lens=(8, 16),
    block_size: int = 16,
    num_blocks=None,
    ttft_slo_steps: float = 12.0,
    tpot_slo_steps: float = 4.0,
    overload: bool = True,
    seed: int = 0,
    print_fn=print,
) -> dict:
    """Chunked vs monolithic prefill under priority load: SLO goodput.

    Identical Poisson workload (every 4th arrival a ``long_len``-token
    low-priority prompt, the rest short high-priority interactive
    requests) served twice through one paged engine — monolithic
    (``chunk_size=0``) then chunked — so both modes share jit caches
    and warm on a same-seeded run. ``overload=True`` under-provisions
    the block pool so high-priority arrivals preempt the long request
    mid-flight (the resume recompute is monolithic's second stall).
    SLO budgets are expressed in decode *steps* (multiples of the
    warmed monolithic p50 step) so the goodput contract is
    machine-speed independent. Token identity chunked == monolithic is
    asserted in both modes — chunking and preemption move work, never
    tokens."""
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.serve.load import make_slo_requests

    # mid-size so the long-prompt prefill stall is compute, not dispatch
    cfg = dataclasses.replace(
        get_config(arch).reduced(),
        num_layers=4, d_model=128, d_ff=384, n_heads=4, n_kv_heads=2, head_dim=32,
    )
    model = Model(cfg)
    params, _ = model.init(jax.random.key(seed))
    # headroom for the pow2-bucketed resume prefill of a preempted long
    # request (effective prompt up to long_len + tokens - 1 → next pow2)
    max_seq = 2 * long_len + 2 * block_size
    max_seq += (-max_seq) % block_size
    if num_blocks is None:
        if overload:
            # one long admission (ceil((long_len + tokens)/bs) blocks)
            # plus ~1.5 shorts: the next high-priority arrival finds the
            # pool dry and must evict the long — preemption by design
            num_blocks = (long_len + tokens + block_size - 1) // block_size + 3
        else:
            num_blocks = max_batch * (max_seq // block_size)

    engine = ServingEngine(
        model, params, max_seq=max_seq, kv_layout="paged",
        block_size=block_size, num_blocks=num_blocks,
    )

    def workload(rng_seed):
        return make_slo_requests(
            n_requests, rate_rps, vocab=cfg.vocab_size, max_new_tokens=tokens,
            short_lens=short_lens, long_len=long_len,
            rng=np.random.default_rng(rng_seed),
        )

    # pre-compile the chunk trace family (closed: pow2 buckets ≤
    # chunk_size) so a resume tail hitting a fresh bucket mid-window
    # can't charge its compile to the chunked p99 — the monolithic
    # stall being measured is prefill COMPUTE, and the comparison
    # should be too
    engine.scheduler(max_batch, seed=seed, chunk_size=chunk_size).prime()

    results, outputs, requests = {}, {}, {}
    slo_ms = None
    for mode, chunk in (("monolithic", 0), ("chunked", chunk_size)):
        engine.serve(workload(seed), max_batch=max_batch, seed=seed,
                     chunk_size=chunk)  # warm jit caches on the same arrivals
        if slo_ms is None:
            # budget in steps × the warmed monolithic median step: the
            # same absolute targets then price both modes
            base = engine.stats.percentile(50)
            slo_ms = (ttft_slo_steps * base, tpot_slo_steps * base)
        reqs = workload(seed)
        out = engine.serve(reqs, max_batch=max_batch, seed=seed, chunk_size=chunk)
        assert all(r.finished for r in reqs), f"{mode}: starved requests"
        results[mode] = dict(
            engine.stats.serving_summary(),
            goodput=_goodput(reqs, slo_ms[0], slo_ms[1]),
        )
        outputs[mode] = [np.asarray(out[r.rid]) for r in reqs]
        requests[mode] = reqs

    for a, b in zip(outputs["monolithic"], outputs["chunked"]):
        np.testing.assert_array_equal(
            a, b, err_msg="chunked prefill changed the decoded tokens"
        )
    if overload:
        assert results["chunked"]["preemptions"] > 0, (
            "overload pool produced no preemptions — pressure knobs too loose"
        )
        assert results["chunked"]["goodput"] > 0, "no request met the SLO"
        assert (
            results["chunked"]["p99_step_ms"] < results["monolithic"]["p99_step_ms"]
        ), "chunking did not cut the p99 decode step"

    ratio = (
        results["monolithic"]["p99_step_ms"] / results["chunked"]["p99_step_ms"]
        if results["chunked"]["p99_step_ms"]
        else 0.0
    )
    summary = {
        "arch": arch,
        "chunk_size": chunk_size,
        "overload": overload,
        "rate_rps": rate_rps,
        "long_len": long_len,
        "block_size": block_size,
        "num_blocks": num_blocks,
        "ttft_slo_ms": slo_ms[0],
        "tpot_slo_ms": slo_ms[1],
        "monolithic": results["monolithic"],
        "chunked": results["chunked"],
        "p99_step_ratio": ratio,
    }
    print_fn("# serving — chunked prefill + priority/preemption SLO goodput")
    print_fn(
        f"arch={arch} requests={n_requests} rate={rate_rps}/s pool={max_batch} "
        f"blocks={num_blocks}x{block_size} chunk={chunk_size} "
        f"overload={overload} slo: ttft<={slo_ms[0]:.1f}ms tpot<={slo_ms[1]:.1f}ms"
    )
    for mode in ("monolithic", "chunked"):
        s = results[mode]
        print_fn(
            f"{mode:10s} goodput={s['goodput']:.2f} "
            f"ttft p99={s['p99_ttft_ms']:.1f}ms "
            f"step p50={s['p50_step_ms']:.2f}ms p99={s['p99_step_ms']:.2f}ms | "
            f"preempt={s['preemptions']} recompute={s['recomputed_tokens']}tok "
            f"qwait p99={s['p99_queue_wait_ms'] or 0:.1f}ms"
        )
    print_fn(f"p99 step: monolithic/chunked = {ratio:.1f}x")
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix reuse mode (paged engine, on vs off)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative-decode mode (n-gram drafter, K sweep vs K=0)")
    ap.add_argument("--backend", metavar="NAME", default=None,
                    choices=("reference", "kernel", "interpret"),
                    help="attention-backend mode: serve both KV layouts through "
                         "NAME and the reference backend, asserting token "
                         "identity (CI kernel smoke: --backend interpret)")
    ap.add_argument("--drift", metavar="PATH", nargs="?", const="drift_decisions.json",
                    default=None,
                    help="online-adviser mode: serve the drifting-"
                         "draftability workload per static K and under the "
                         "closed-loop controller (token identity, zero "
                         "retraces after prime, controller beats the worst "
                         "static arm — CI drift smoke), writing the decision "
                         "audit trail to PATH (default drift_decisions.json)")
    ap.add_argument("--chunked", action="store_true",
                    help="SLO-goodput mode: chunked vs monolithic prefill on "
                         "the mixed-priority workload")
    ap.add_argument("--overload", action="store_true",
                    help="with --chunked: under-provision the paged pool so "
                         "preemption fires (CI overload smoke)")
    ap.add_argument("--trace", metavar="PATH", nargs="?", const="serving_trace.json",
                    default=None,
                    help="observability mode: serve one workload with the "
                         "flight recorder off and on (token identity + "
                         "overhead guard asserted) and export Chrome/"
                         "Perfetto trace-event JSON to PATH (default "
                         "serving_trace.json; load in ui.perfetto.dev or "
                         "chrome://tracing)")
    ap.add_argument("--mesh", metavar="N[xM]", default=None,
                    help="sharded mode. N: serve one workload at every "
                         "power-of-two mesh size up to N through the "
                         "head-partitioned paged path, asserting bitwise "
                         "token identity vs single-device (CI multi-device "
                         "smoke: --mesh 4). NxM: a 2D ('model','seq') sweep "
                         "— head-only N (bitwise), seq-only M and NxM "
                         "(argmax token identity, the kv-sequence-split "
                         "tolerance lane; CI smoke: --mesh 2x2)")
    args = ap.parse_args()
    if args.shared_prefix:
        run_shared_prefix()
    elif args.speculative:
        run_speculative()
    elif args.backend:
        run_backend_sweep(backends=("reference", args.backend))
    elif args.drift:
        run_drift(decisions_path=args.drift)
    elif args.trace:
        run_observability(trace_path=args.trace)
    elif args.chunked:
        run_slo(overload=args.overload)
    elif args.mesh:
        if "x" in args.mesh:
            tp, sp = (int(v) for v in args.mesh.split("x"))
            run_sharded(mesh_sizes=(1, tp), seq_shapes=((sp,), (tp, sp)))
        else:
            n = int(args.mesh)
            run_sharded(
                mesh_sizes=tuple(
                    2 ** i for i in range(n.bit_length()) if 2 ** i <= n
                ),
                seq_shapes=(),
            )
    else:
        run()
