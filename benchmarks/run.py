"""Benchmark harness — one section per paper figure/table.

  Fig.1/Fig.2  granularity sweeps (PFL compute-bound, CC memory-bound)
  Fig.3/Fig.4  Aira end-to-end over the 10 latency-critical benchmarks
  §Roofline    per-(arch × shape) roofline terms from the dry-run
  µbench       CPU wall-clock of each benchmark's serial JAX kernel
               (``name,us_per_call,derived`` CSV)

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import sys
import time

import jax


def _microbench(print_fn=print):
    from repro.bench_suite import BENCHMARKS

    print_fn("# µbench — serial kernel wall-clock (CPU, one iteration)")
    print_fn("name,us_per_call,derived")
    for name, b in BENCHMARKS.items():
        data = b.build()
        f = jax.jit(b.serial_value)
        jax.block_until_ready(f(data))
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            jax.block_until_ready(f(data))
        us = (time.perf_counter() - t0) / reps * 1e6
        n = jax.tree.leaves(b.items(data))[0].shape[0]
        print_fn(f"{name},{us:.1f},items={n}")


def main() -> None:
    fast = "--fast" in sys.argv
    from benchmarks import fig12_granularity, fig34_aira, roofline

    fig12_granularity.run()
    print()
    fig34_aira.run(timing=not fast)
    print()
    roofline.run()
    print()
    if not fast:
        _microbench()


if __name__ == "__main__":
    main()
