"""Benchmark harness — one section per paper figure/table.

  Fig.1/Fig.2  granularity sweeps (PFL compute-bound, CC memory-bound)
  Fig.3/Fig.4  Aira end-to-end over the 10 latency-critical benchmarks
  §Roofline    per-(arch × shape) roofline terms from the dry-run
  µbench       CPU wall-clock of each benchmark's serial JAX kernel
               (``name,us_per_call,derived`` CSV)
  §Serving     open-loop Poisson-arrival load on the continuous-batching
               serving core (p50/p99 TTFT, per-token latency), plus the
               shared-prefix reuse-on/off TTFT comparison on the paged
               KV cache and the speculative-decode K-sweep (n-gram
               drafter vs the K=0 baseline, acceptance rate + advised
               depth)

Every run writes ``BENCH_aira.json`` — per-benchmark predicted/realized
gain plus the µbench wall-clock — so the perf trajectory is machine-
readable across PRs. ``--fast`` skips the restructured-vs-serial timing
comparison but still emits the summary (fewer µbench reps).

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import json
import sys
import time

import jax


def _microbench(print_fn=print, reps: int = 5) -> dict[str, float]:
    from repro.bench_suite import BENCHMARKS

    print_fn("# µbench — serial kernel wall-clock (CPU, one iteration)")
    print_fn("name,us_per_call,derived")
    out = {}
    for name, b in BENCHMARKS.items():
        data = b.build()
        f = jax.jit(b.serial_value)
        jax.block_until_ready(f(data))
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f(data))
        us = (time.perf_counter() - t0) / reps * 1e6
        n = jax.tree.leaves(b.items(data))[0].shape[0]
        print_fn(f"{name},{us:.1f},items={n}")
        out[name] = us
    return out


def write_summary(rows, gm_pos, gm_all, ubench_us, serving=None, path="BENCH_aira.json") -> None:
    """Machine-readable per-PR perf summary (predicted gains are the
    calibrated overlap model; µbench is measured CPU wall-clock;
    ``serving`` is the open-loop load test's p50/p99 TTFT + per-token
    latency from benchmarks/serving_load.py, including the
    ``shared_prefix`` reuse-on/off comparison on the paged engine, the
    ``speculative`` K-sweep vs the K=0 greedy baseline, and the
    ``attention_backend`` sweep — p50 TPOT and per-step attention time
    per (KV layout × backend) plus the KernelAdvisorTool's measured
    backend decision — and the ``sharded`` mesh sweep's per-step
    latency at mesh sizes {1,2,4} under bitwise token identity, plus
    the ``online_adviser`` drift benchmark — closed-loop controller
    p50 TPOT vs every static K arm and the per-phase-best oracle)."""
    summary = {
        "benchmarks": [
            {
                "name": r["name"],
                "accepted": r["accepted"],
                "schedule": r["schedule"],
                "predicted_gain": r["predicted"],
                "realized_gain_model": r["realized"],
                # predicted-vs-realized sign gate (fig34_aira.flag_
                # regressions): accepted on a positive prediction but
                # realized negative — Fig. 4's forced rows carry it
                "regressed": r.get("regressed", False),
                "ubench_serial_us": ubench_us.get(r["name"]),
            }
            for r in rows
        ],
        "geomean_positive": gm_pos,
        "geomean_all_discard_negative": gm_all,
    }
    if serving is not None:
        summary["serving"] = serving
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, default=float)
    print(f"wrote {path}")


def main() -> None:
    fast = "--fast" in sys.argv
    from benchmarks import fig12_granularity, fig34_aira, roofline, serving_load

    fig12_granularity.run()
    print()
    rows, gm_pos, gm_all = fig34_aira.run(timing=not fast)
    print()
    roofline.run()
    print()
    ubench_us = _microbench(reps=2 if fast else 5)
    print()
    serving = serving_load.run(
        n_requests=6 if fast else 12, tokens=4 if fast else 8
    )
    print()
    # not reduced under --fast: the reuse-on/off TTFT comparison needs
    # enough requests for stable percentiles, and runs in seconds anyway
    serving["shared_prefix"] = serving_load.run_shared_prefix()
    print()
    # likewise un-reduced: the K-sweep's token-identity and nonzero-
    # acceptance asserts are the tracked speculative-decode contract
    serving["speculative"] = serving_load.run_speculative()
    print()
    # attention-backend sweep: reference vs the block-paged kernel (in
    # interpret mode on CPU CI), token-identity asserted per layout,
    # advised backend from the measured per-step cost (DESIGN.md §4)
    serving["attention_backend"] = serving_load.run_backend_sweep()
    print()
    # SLO-attainment goodput under overload: chunked vs monolithic
    # prefill on the mixed-priority workload, preemption pressure on —
    # the chunked-p99-step and nonzero-goodput asserts are the tracked
    # scheduling contract (DESIGN.md §3.3)
    serving["slo"] = serving_load.run_slo(overload=True)
    print()
    # mesh-sharded paged decode at mesh sizes {1,2,4} on one workload:
    # bitwise token identity vs the single-device paged path (plain,
    # speculative, chunked) plus per-step latency per mesh size — the
    # tracked tensor-parallel serving contract (DESIGN.md §5). Runs in
    # a forced multi-device CPU subprocess when this process has one
    # real device (the normal CI case).
    serving["sharded"] = serving_load.run_sharded()
    print()
    # flight-recorder contract: telemetry-on serves the same workload
    # token-identically through the same warmed engine, the paired-rep
    # p50-step overhead stays under the pinned factor, and the exported
    # trace validates (DESIGN.md §8)
    serving["observability"] = serving_load.run_observability()
    print()
    # online adaptive adviser on the drifting-draftability workload:
    # the closed-loop controller must beat the worst static K arm's p50
    # TPOT, track the per-phase-best oracle within tolerance, and
    # switch retrace-free through the primed step grid (DESIGN.md §9)
    serving["online_adviser"] = serving_load.run_drift()
    write_summary(rows, gm_pos, gm_all, ubench_us, serving=serving)


if __name__ == "__main__":
    main()
