"""Figs. 1–2: SMT/SMP gains vs kernel granularity (PFL and CC).

Sweeps the kernel size (the paper varies 'the corresponding parameters')
and prices 2-thread Relic / OpenMP schedules on one SMT core vs two
physical cores with the calibrated i7-12700 hardware model. Reproduction
anchors: PFL@1000 ≈ +5% Relic-SMT / +2.7% OMP-SMT; CC shows a band
where Relic-SMT is positive and above SMP while OpenMP is negative.
"""
from __future__ import annotations

from repro.bench_suite import cc, pfl
from repro.core.overlap_model import CPU_HW, OPENMP, RELIC, Microtask, OverlapModel

SIZES = (10, 25, 50, 100, 200, 500, 1000, 2000, 4000, 8000, 16000)


def sweep(base: Microtask, sizes=SIZES):
    model = OverlapModel(CPU_HW)
    rows = []
    for n in sizes:
        row = {"n": n}
        for rt in (RELIC, OPENMP):
            # Relic: fine dynamically-dealt microtasks; OpenMP: static split
            g = max(4, n // 4) if rt.name == "relic" else max(1, n // 2)
            task = Microtask(base.flops * g, base.bytes * g, base.chain * g, base.vector)
            p = model.predict(task, max(2, n // g), runtime=rt)
            row[f"{rt.name}_smt"] = p.gain("smt2")
            row[f"{rt.name}_smp"] = p.gain("smp2")
        rows.append(row)
    return rows


def run(print_fn=print):
    out = {}
    for fig, (name, mod) in enumerate(
        [("PFL-motion-update", pfl), ("CC", cc)], start=1
    ):
        rows = sweep(mod.microtask())
        out[name] = rows
        print_fn(f"# Fig.{fig} — {name}: gain vs granularity (2 threads)")
        print_fn("n,relic_smt,relic_smp,openmp_smt,openmp_smp")
        for r in rows:
            print_fn(
                f"{r['n']},{r['relic_smt']*100:+.1f}%,{r['relic_smp']*100:+.1f}%,"
                f"{r['openmp_smt']*100:+.1f}%,{r['openmp_smp']*100:+.1f}%"
            )
        model = OverlapModel(CPU_HW)
        band = model.profitable_band(mod.microtask(), 16000)
        print_fn(f"relic smt-wins-band (items grouped ≥): {band}")
    return out
