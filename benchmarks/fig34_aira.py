"""Figs. 3–4 + §VII: end-to-end Aira over the 10 latency-critical
benchmarks — gate decisions, per-benchmark gains, geomeans.

Expected reproduction pattern (paper §VII):
  * 7/10 parallelized with positive predicted gain (geomean ≈ 25.2%),
  * Fraud rejected by the overlap-simulator gate (no change),
  * 1-Hop and BVH pass the gate but sit below the Relic granularity
    floor; force-applying them realizes −9% / −61% (locality break +
    per-item dispatch), reproducing Fig. 4,
  * geomean over all 10 with non-applied = 1.0 ⇒ ≈ 17%.

The whole figure now flows through the plan layer: ``advise_suite``
batch-advises every registered benchmark via the tool pipeline, and the
restructured wall-clock is measured by executing each benchmark's cached
``RegionPlan`` (so re-running the figure re-uses compiled plans).

CPU wall-clock of serial vs restructured JAX is printed as a sanity
reference (vectorization effects, not SMT — the gains column is the
calibrated i7-12700 dual-stream model, see DESIGN.md §2).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.bench_suite import BENCHMARKS
from repro.core import Workload
from repro.core.overlap_model import CPU_HW, Microtask, OverlapModel
from repro.core.plan import advise_suite


def make_workload(b, data) -> Workload:
    """The benchmark's single-region workload (kept for callers that
    advise one benchmark at a time; ``advise_suite`` covers the set)."""
    return b.workload(data)


def realized_gain(b, data, decision) -> float:
    """Measured-outcome model: accepted → predicted gain; rejected → 0;
    forced below the Relic floor → granularity-1 schedule with locality
    break (paper Fig. 4)."""
    if not decision.accepted:
        return 0.0
    if not b.force:
        return decision.predicted_gain
    model = OverlapModel(CPU_HW)
    c = b.cost(data)
    pen = 1.0 + b.locality_penalty
    n = int(np.asarray(jax.tree.leaves(b.items(data))[0]).shape[0])
    g = max(1, b.realized_granularity)
    base = Microtask(c["flops"], c["bytes"], max(0, c["chain"]), c.get("vector", True))
    task = Microtask(
        flops=c["flops"] * g,
        bytes=c["bytes"] * g * pen,
        chain=max(1, int(round(c["chain"] * g * pen))),
        vector=c.get("vector", True),
    )
    p = model.predict(task, max(1, n // g))
    # realized gain compares the DEGRADED schedule to the ORIGINAL serial
    serial_orig = model.predict(base, n).serial
    return serial_orig / p.smt2 - 1.0


def flag_regressions(rows) -> list:
    """Predicted-vs-realized sign gate (in place, returned for chaining):
    a row the gate ACCEPTED on a positive predicted gain whose realized
    model says it got *slower* is flagged ``regressed: True``. The
    ``accepted`` bit is deliberately kept — the forced rows reproduce the
    paper's Fig. 4 (accept-then-regret is the datum) — but the flag makes
    the sign disagreement machine-readable instead of a footnote in the
    decision column, so downstream consumers (BENCH diffing, the adviser's
    calibration loop) never mistake a forced regression for a win."""
    for r in rows:
        r["regressed"] = bool(
            r["accepted"] and r["predicted"] > 0 and r["realized"] < 0
        )
    return rows


def _wall(thunk, reps=3) -> float:
    jax.block_until_ready(thunk())  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(thunk())
    return (time.perf_counter() - t0) / reps * 1e3


def run(print_fn=print, timing: bool = True):
    suite = advise_suite(hw=CPU_HW)
    rows = []
    for name, entry in suite.items():
        b, data, d = BENCHMARKS[name], entry.data, entry.decision
        rg = realized_gain(b, data, d)
        wall_serial = wall_par = float("nan")
        if timing:
            comb = b.combine
            f = jax.jit(lambda dd: b.serial_value(dd, combine=comb))
            wall_serial = _wall(lambda: f(data))
            if entry.plan is not None:
                items = b.items(data)
                wall_par = _wall(lambda: entry.plan.execute(items))
            else:  # rejected: time the would-be restructuring anyway
                wall_par = _wall(lambda: b.parallel_value(data, granularity=8, combine=comb))
        rows.append(
            dict(
                name=name,
                accepted=d.accepted,
                schedule=d.schedule.describe() if d.schedule else "-",
                predicted=d.predicted_gain,
                realized=rg,
                wall_serial_ms=wall_serial,
                wall_restructured_ms=wall_par,
                log=d.stage_log,
            )
        )

    flag_regressions(rows)
    print_fn("# Fig.3/4 — Aira end-to-end on 10 latency-critical benchmarks")
    print_fn("benchmark,decision,predicted,realized_model,wall_serial_ms,wall_restruct_ms")
    for r in rows:
        dec = "accept" if r["accepted"] else "reject(gate)"
        if r["regressed"]:
            dec = "accept(forced,regressed)"
        print_fn(
            f"{r['name']},{dec},{r['predicted']*100:+.1f}%,{r['realized']*100:+.1f}%,"
            f"{r['wall_serial_ms']:.2f},{r['wall_restructured_ms']:.2f}"
        )

    pos = [r["realized"] for r in rows if r["realized"] > 0]
    all10 = [max(r["realized"], 0.0) if r["realized"] > 0 or not r["accepted"] else 0.0 for r in rows]
    # paper headline numbers: geomean over positives; geomean over all 10
    # with non-improved treated as 1.0 (outliers discarded in production)
    gm_pos = float(np.exp(np.mean(np.log1p(pos)))) - 1 if pos else 0.0
    gm_all = float(np.exp(np.mean(np.log1p([max(x, 0.0) for x in all10])))) - 1
    n_ok = sum(r["realized"] > 0 for r in rows)
    print_fn(
        f"successfully parallelized {n_ok}/10 (paper: 7/10); "
        f"geomean(positive)={gm_pos*100:.1f}% (paper: 25.2%); "
        f"geomean(all, negatives discarded)={gm_all*100:.1f}% (paper: 17%)"
    )
    return rows, gm_pos, gm_all
