"""SSD (Mamba2) chunked scan as a Pallas TPU kernel.

One program instance owns one (batch, head) pair; the chunk axis is the
sequential grid dim, carrying the [hd, N] state in VMEM scratch. Within a
chunk the recurrence is the quadratic SSD contraction (MXU work); between
chunks only the state survives — the DMA stream prefetches the next
chunk's x/B/C blocks while the MXU processes the current one (the same
Relic pair-scheduling as relic_matmul, applied to a recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, dt_ref, y_ref, state_ref, *, Q):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # [Q, hd]
    a = a_ref[0, 0, 0].astype(jnp.float32)  # [Q, 1] decay per step
    b = b_ref[0, 0].astype(jnp.float32)  # [Q, N]
    c = c_ref[0, 0].astype(jnp.float32)  # [Q, N]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # [Q, 1]

    la = jnp.cumsum(jnp.log(jnp.maximum(a, 1e-20)), axis=0)  # [Q,1]
    # intra-chunk causal quadratic: att[i,j] = (c_i·b_j)·exp(la_i-la_j)·dt_j
    seg = jnp.exp(jnp.clip(la - la.T, -60.0, 0.0))  # [Q,Q]
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )
    att = jnp.where(causal, cb * seg * dt.T, 0.0)
    y = jax.lax.dot_general(
        att, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # inter-chunk: y_i += exp(la_i) · c_i · S_prev
    s_prev = state_ref[...]  # [N, hd]
    y += jnp.exp(la) * jax.lax.dot_general(
        c, s_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # state update: S' = exp(la_end)·S + Σ_j (b_j·dt_j·exp(la_end-la_j)) ⊗ x_j
    w = dt * jnp.exp(jnp.clip(la[-1:] - la, -60.0, 0.0))  # [Q,1]
    state_ref[...] = jnp.exp(la[-1]) * s_prev + jax.lax.dot_general(
        b * w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [N, hd]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan(
    xh: jax.Array,
    a: jax.Array,
    b: jax.Array,
    c: jax.Array,
    dt: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """xh [B,S,H,hd]; a,dt [B,S,H]; b,c [B,S,N] → y [B,S,H,hd]."""
    B, S, H, hd = xh.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xr = xh.transpose(0, 2, 1, 3).reshape(B, H, nc, Q, hd)
    ar = a.transpose(0, 2, 1).reshape(B, H, nc, Q, 1)
    dtr = dt.transpose(0, 2, 1).reshape(B, H, nc, Q, 1)
    br = b.reshape(B, nc, Q, N)
    cr = c.reshape(B, nc, Q, N)

    grid = (B, H, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, hd), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, Q, hd), lambda ib, ih, ic: (ib, ih, ic, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Q, hd), xh.dtype),
        scratch_shapes=[pltpu.VMEM((N, hd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xr, ar, br, cr, dtr)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
