"""relic_matmul — the Relic SMT-pair analogue on one TensorCore.

The paper's Relic runtime co-schedules a *memory-bound* and a *compute-
bound* microtask stream onto the two hardware threads of one SMT core.
The TPU-native translation: a Pallas grid pipeline in which the DMA engine
(HBM→VMEM block prefetch, the "memory thread") runs concurrently with the
MXU contraction on the previously fetched block (the "compute thread").
Pallas double-buffers each BlockSpec'd operand across sequential grid
steps, so grid step k computes x[i,k]·w[k,j] while k+1's blocks stream in
— exactly the paired-stream structure of Relic, with the block shape as
the task granularity (the paper's Figs. 1–2 sweep; see
core/overlap_model.py for the granularity band this implies).

Block shapes are MXU-aligned (multiples of 128 on contraction/lane dims)
and sized so 2 in-flight copies of each operand block + the fp32
accumulator fit VMEM (~16 MB budget is checked in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref):
    # "compute thread": contract the block the DMA stream fetched last step
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def relic_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x [M,K] @ w [K,N] with explicit double-buffered block pipeline."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bk, bn = min(bm, M), min(bk, K), min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (x.shape, w.shape, (bm, bk, bn))
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w)


def _gemv_kernel(x_ref, w_ref, o_ref, acc_ref):
    """Decode GEMV: tall-skinny activation block × weight panel.

    The memory stream (weight panels, the dominant bytes at batch≲8) hides
    behind the MXU stream — the latency-critical decode case of the paper.
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def relic_gemv(
    x: jax.Array, w: jax.Array, *, bk: int = 1024, bn: int = 512, interpret: bool = False
) -> jax.Array:
    """x [B,K] @ w [K,N] for small B (decode): grid streams weight panels."""
    B, K = x.shape
    K2, N = w.shape
    bk, bn = min(bk, K), min(bn, N)
    assert K % bk == 0 and N % bn == 0
    grid = (N // bn, K // bk)
    return pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk, bn), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, w)
