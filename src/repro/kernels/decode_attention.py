"""Flash-decode: single-token attention over a long KV cache.

This is the paper's latency-critical regime transplanted to TPU: one query
token, a huge memory-bound KV stream, near-idle MXU. The kernel pipelines
cache blocks (DMA "memory thread") against the tiny logits/PV contractions
("compute thread") with running max/sum in VMEM scratch — the SMT-pair
co-scheduling that recovers the idle resource.

Grid (B, KV, S/bk): sequential cache-block axis innermost; the g query
heads of each kv group ride in the sublane dim.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, bk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_len = len_ref[0]  # scalar int32 for this batch row
    # skip cache blocks entirely past the valid length ("memory thread"
    # stops streaming once the data is dead — Relic's early task retire)
    @pl.when(ik * bk < valid_len)
    def _step():
        q = q_ref[0, 0]  # [g, hd]
        k = k_ref[0, 0]  # [bk, hd]
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [g, bk]
        pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < valid_len
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q [B,H,hd]; caches [B,Smax,KV,hd]; cache_len [B] → out [B,H,hd]."""
    B, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    bk = min(bk, Smax)
    assert Smax % bk == 0
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(B, KV, g, hd)
    kr = k_cache.transpose(0, 2, 1, 3)  # [B, KV, Smax, hd]
    vr = v_cache.transpose(0, 2, 1, 3)

    grid = (B, KV, Smax // bk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, kv, ik: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda b, kv, ik: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, ik: (b, kv, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, ik: (b, kv, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, kv, ik: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(cache_len, qr, kr, vr)
    return out.reshape(B, H, hd)
