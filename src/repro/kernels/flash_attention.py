"""Causal flash attention (prefill/train) as a Pallas TPU kernel.

Grid (B·KV, Sq/bq, Skv/bk): the KV-block stream (DMA "memory thread")
pipelines against the MXU logits/PV contractions ("compute thread");
running max/sum/acc live in VMEM scratch across the sequential kv axis.
Causal block-skipping: kv blocks strictly above the diagonal are skipped
with ``pl.when`` — this is the FLOP saving the pure-jnp `masked` path
cannot express (EXPERIMENTS.md §Perf hillclimb #prefill).

GQA is handled by the index map (query heads of one kv group share the
kv block) without materializing repeated K/V.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, bq, bk, causal):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip kv blocks strictly above the diagonal (the ½-FLOP win)
    run = (ik * bk < (iq + 1) * bq) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0, 0]  # [g*bq, hd] — g query heads × bq rows flattened
        k = k_ref[0]  # [bk, hd]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [g*bq, bk]
        if causal:
            g_bq = q.shape[0]
            q_pos = iq * bq + (jax.lax.broadcasted_iota(jnp.int32, (g_bq, bk), 0) % bq)
            k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (g_bq, bk), 1)
            mask = q_pos >= k_pos
            s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q [B,Sq,H,hd]; k,v [B,Skv,KV,hd] → [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    bq, bk = min(bq, Sq), min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    scale = 1.0 / math.sqrt(hd)

    # layout: fold (B, KV) into the leading grid axis; queries of one kv
    # group are flattened into the row dim so one kv block serves g heads.
    qr = (
        q.reshape(B, Sq // bq, bq, KV, g, hd)
        .transpose(0, 3, 1, 4, 2, 5)
        .reshape(B * KV, (Sq // bq), g * bq, hd)
    )
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)

    grid = (B * KV, Sq // bq, Skv // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g * bq, hd), lambda bh, iq, ik: (bh, iq, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (bh, ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (bh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * bq, hd), lambda bh, iq, ik: (bh, iq, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, Sq // bq, g * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, 1), jnp.float32),
            pltpu.VMEM((g * bq, hd), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qr, kr, vr)
    out = (
        out.reshape(B, KV, Sq // bq, g, bq, hd)
        .transpose(0, 2, 4, 1, 3, 5)
        .reshape(B, Sq, H, hd)
    )
    return out
