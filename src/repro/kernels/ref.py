"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def attention_ref(q, k, v, causal=True):
    """Naive full-softmax attention. q [B,Sq,H,hd], k/v [B,Skv,KV,hd]."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    g = H // KV
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
    if causal:
        q_pos = jnp.arange(Sq) + (Skv - Sq)
        mask = q_pos[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """q [B,H,hd]; caches [B,Smax,KV,hd]; cache_len [B]."""
    B, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    kc = jnp.repeat(k_cache, g, axis=2)
    vc = jnp.repeat(v_cache, g, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q, kc).astype(jnp.float32) / math.sqrt(hd)
    valid = jnp.arange(Smax)[None, :] < cache_len[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p.astype(vc.dtype), vc)


def paged_attention_ref(q, k_pool, v_pool, block_tables, lengths, k_scale=None, v_scale=None):
    """Dense-gather oracle for the block-paged decode/verify kernel.

    q [B,T,H,hd]; pools [NB,BS,KV,hd]; tables [B,MB]; lengths [B]
    (query t attends positions < lengths + t + 1). int8 pools pass
    per-vector scales [NB,BS,KV]."""
    B, T, H, hd = q.shape
    BS, KV = k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    g = H // KV
    kd = jnp.take(k_pool, block_tables, axis=0)  # [B, MB, BS, KV, hd]
    vd = jnp.take(v_pool, block_tables, axis=0)
    kd = kd.reshape(B, MB * BS, KV, hd).astype(jnp.float32)
    vd = vd.reshape(B, MB * BS, KV, hd).astype(jnp.float32)
    if k_scale is not None:
        ks = jnp.take(k_scale, block_tables, axis=0).reshape(B, MB * BS, KV)
        vs = jnp.take(v_scale, block_tables, axis=0).reshape(B, MB * BS, KV)
        kd = kd * (ks.astype(jnp.float32) / 127.0)[..., None]
        vd = vd * (vs.astype(jnp.float32) / 127.0)[..., None]
    kd = jnp.repeat(kd, g, axis=2)
    vd = jnp.repeat(vd, g, axis=2)
    s = jnp.einsum("bthd,bshd->bths", q.astype(jnp.float32), kd) / math.sqrt(hd)
    pos = jnp.arange(MB * BS)[None, None, :]
    valid = pos < (lengths[:, None] + jnp.arange(T)[None, :] + 1)[:, :, None]
    s = jnp.where(valid[:, :, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bths,bshd->bthd", p, vd).astype(q.dtype)


def ssd_ref(xh, a, b, c, dt):
    """Sequential (unchunked) SSD recurrence — the ground truth.

    xh [B,S,H,hd]; a [B,S,H] (decay = exp(dt·A)); b,c [B,S,N]; dt [B,S,H].
    h_t = a_t·h_{t-1} + dt_t·(b_t ⊗ x_t);  y_t = c_t·h_t
    """
    B, S, H, hd = xh.shape
    N = b.shape[-1]

    def step(state, args):
        x_t, a_t, b_t, c_t, dt_t = args
        state = state * a_t[..., None, None] + jnp.einsum(
            "bhd,bn,bh->bhdn", x_t.astype(jnp.float32), b_t.astype(jnp.float32), dt_t
        )
        y = jnp.einsum("bn,bhdn->bhd", c_t.astype(jnp.float32), state)
        return state, y

    s0 = jnp.zeros((B, H, hd, N), jnp.float32)
    xs = (
        xh.swapaxes(0, 1),
        a.swapaxes(0, 1),
        b.swapaxes(0, 1),
        c.swapaxes(0, 1),
        dt.swapaxes(0, 1),
    )
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(xh.dtype)  # [B,S,H,hd]
