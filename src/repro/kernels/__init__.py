# Pallas kernel layer: ops.py is the public dispatch surface (backend
# registry + jit'd wrappers), ref.py the pure-jnp oracles, the rest the
# kernels themselves. Model/serving code imports ops, never a kernel
# module directly (DESIGN.md §4).
