"""Block-paged flash-decode/verify: attention straight off the block pool.

The serving layer's paged decode used to materialize a dense
``[B, MB·BS]`` copy of every row's KV through ``gather_block_rows``
before attending — a per-step bandwidth tax proportional to the pool's
*capacity*, not its contents. This kernel walks each row's block table
instead: the index_map reads the table (scalar-prefetched into SMEM)
and DMAs KV blocks directly from the paged pool, so the "memory
thread" streams exactly the blocks the row owns while the "compute
thread" runs the running-max softmax in VMEM — the same SMT-pair
co-scheduling as ``decode_attention``, now addressed through pages.

Grid ``(B, KV, MB)``: the sequential block-table axis is innermost;
the T·g query rows of each kv group ride in the sublane dim. T is
static — T=1 is plain decode, T=K+1 the speculative verify (query t
attends positions < len + t + 1, so the masked reduction per query is
bitwise the one the sequential decode would run: blocks wholly past a
query's window contribute exp-weights of exactly zero and a
correction factor of exactly one). Unowned table entries point at the
pool's null block, whose data is masked off by ``lengths`` — every
table entry is therefore always a safe DMA source. int8-KV pools
dequantize in-kernel (per-vector scales ride in their own prefetched
blocks), halving the streamed bytes vs a dense bf16 gather.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG = -1e30


def _paged_kernel(
    tbl_ref,  # [B, MB] int32 (scalar prefetch)
    len_ref,  # [B] int32 (scalar prefetch)
    q_ref,  # [1, 1, T·g, hd]
    k_ref,  # [1, BS, 1, hd]
    v_ref,
    o_ref,  # [1, 1, T·g, hd]
    m_ref,  # [T·g, 1] f32 scratch
    l_ref,
    acc_ref,  # [T·g, hd] f32 scratch
    *,
    scale,
    bs,
    t,
    g,
    ks_ref=None,  # [1, BS, 1] per-vector scales (int8 pools)
    vs_ref=None,
    own_ref=None,  # [B, MB] int32 ownership (scalar prefetch, seq split)
    om_ref=None,  # [1, 1, T·g, 1] partials outputs (seq split)
    ol_ref=None,
):
    b = pl.program_id(0)
    mb = pl.program_id(2)

    @pl.when(mb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = len_ref[b]  # committed length; query t sees pos < base + t + 1
    # skip blocks wholly past the last query's window ("memory thread"
    # stops streaming dead data — Relic's early task retire). Under the
    # kv-sequence split, blocks this rank's shard does not own are
    # skipped the same way — ownership is block-granular, so the mask
    # needs no per-position term
    live = mb * bs < base + t
    if own_ref is not None:
        live = jnp.logical_and(live, own_ref[b, mb] != 0)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0]  # [T·g, hd]
        k = k_ref[0, :, 0]  # [BS, hd]
        v = v_ref[0, :, 0]
        if ks_ref is not None:  # dequantize in-kernel: int8 · scale/127
            k = k.astype(jnp.float32) * (
                ks_ref[0, :, 0].astype(jnp.float32) / 127.0
            )[:, None]
            v = v.astype(jnp.float32) * (
                vs_ref[0, :, 0].astype(jnp.float32) / 127.0
            )[:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [T·g, BS]
        pos = mb * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        tq = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
        mask = pos < base + tq + 1
        s = jnp.where(mask, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(mb == pl.num_programs(2) - 1)
    def _flush():
        if om_ref is None:
            o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
                o_ref.dtype
            )
        else:
            # partials mode: emit the unnormalized flash triple — the
            # cross-rank distributed_softmax combine normalizes. An
            # all-skipped shard flushes (NEG, 0, 0), which the combine's
            # empty-shard guard scales to exactly zero
            o_ref[0, 0] = acc_ref[...]
            om_ref[0, 0] = m_ref[...]
            ol_ref[0, 0] = l_ref[...]


def paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    owned: jax.Array | None = None,
    partials: bool = False,
    interpret: bool = False,
):
    """q [B,T,H,hd]; pools [NB,BS,KV,hd]; tables [B,MB] int32 block ids;
    lengths [B] committed lengths (query t valid positions are
    < lengths + t + 1) → out [B,T,H,hd]. int8 pools pass per-vector
    ``k_scale``/``v_scale`` [NB,BS,KV] and dequantize in-kernel.

    kv-sequence split (DESIGN.md §5): ``owned`` [B, MB] marks the table
    entries whose blocks live in this rank's pool shard (unowned entries
    must already point at a safe local scratch slot — they are skipped,
    never streamed into the softmax). ``partials=True`` returns the
    unnormalized flash triple ``(m [B,T,H], l [B,T,H], acc [B,T,H,hd]
    float32)`` instead of the normalized output, for the cross-rank
    ``distributed_softmax`` combine."""
    B, T, H, hd = q.shape
    NB, BS, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    # the g query heads of each kv group — and the T verify queries —
    # ride together in the sublane dim: [B, KV, T·g, hd]
    qr = q.reshape(B, T, KV, g, hd).transpose(0, 2, 1, 3, 4).reshape(B, KV, T * g, hd)

    grid = (B, KV, MB)
    # index maps take *pref so one lambda serves both prefetch layouts
    # (tbl, lens) and (tbl, lens, owned)
    kv_spec = pl.BlockSpec(
        (1, BS, 1, hd), lambda b, kv, mb, *pref: (pref[0][b, mb], 0, kv, 0)
    )
    q_spec = pl.BlockSpec((1, 1, T * g, hd), lambda b, kv, mb, *pref: (b, kv, 0, 0))
    in_specs = [q_spec, kv_spec, kv_spec]
    operands = [qr, k_pool, v_pool]
    if quant:
        sc_spec = pl.BlockSpec(
            (1, BS, 1), lambda b, kv, mb, *pref: (pref[0][b, mb], 0, kv)
        )
        in_specs += [sc_spec, sc_spec]
        operands += [k_scale, v_scale]

    prefetch = [block_tables.astype(jnp.int32), lengths.astype(jnp.int32)]
    if owned is not None:
        prefetch.append(owned.astype(jnp.int32))

    if partials:
        ml_spec = pl.BlockSpec((1, 1, T * g, 1), lambda b, kv, mb, *pref: (b, kv, 0, 0))
        out_specs = (q_spec, ml_spec, ml_spec)
        out_shape = (
            jax.ShapeDtypeStruct((B, KV, T * g, hd), jnp.float32),  # acc
            jax.ShapeDtypeStruct((B, KV, T * g, 1), jnp.float32),  # m
            jax.ShapeDtypeStruct((B, KV, T * g, 1), jnp.float32),  # l
        )
    else:
        out_specs = q_spec
        out_shape = jax.ShapeDtypeStruct((B, KV, T * g, hd), q.dtype)

    n_pref = len(prefetch)
    n_in = len(operands)
    n_out = 3 if partials else 1

    def kernel(*refs):
        tbl, lens = refs[0], refs[1]
        own = refs[2] if owned is not None else None
        i = n_pref
        qf, kf, vf = refs[i : i + 3]
        i += 3
        ksf, vsf = (refs[i], refs[i + 1]) if quant else (None, None)
        i = n_pref + n_in
        of = refs[i]
        omf, olf = (refs[i + 1], refs[i + 2]) if partials else (None, None)
        mf, lf, accf = refs[i + n_out : i + n_out + 3]
        return _paged_kernel(
            tbl, lens, qf, kf, vf, of, mf, lf, accf,
            scale=scale, bs=BS, t=T, g=g, ks_ref=ksf, vs_ref=vsf,
            own_ref=own, om_ref=omf, ol_ref=olf,
        )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_pref,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((T * g, 1), jnp.float32),
                pltpu.VMEM((T * g, 1), jnp.float32),
                pltpu.VMEM((T * g, hd), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*prefetch, *operands)

    def heads_out(x, d):
        return x.reshape(B, KV, T, g, d).transpose(0, 2, 1, 3, 4).reshape(B, T, H, d)

    if not partials:
        return heads_out(out, hd)
    acc, m, l = out
    return (
        heads_out(m, 1).reshape(B, T, H),
        heads_out(l, 1).reshape(B, T, H),
        heads_out(acc, hd),
    )
