"""jit'd public wrappers for the Pallas kernels + the backend registry.

Each wrapper validates shapes, checks the VMEM working-set budget implied
by the chosen block shapes (double-buffered operands + scratch must fit),
and dispatches kernel vs. pure-jnp reference:

  on TPU            → the Pallas kernel (compiled by Mosaic)
  on CPU, testing   → the kernel in interpret mode (correctness)
  on CPU, dry-run   → the jnp reference (so SPMD partitioning & the
                      roofline read clean HLO; see DESIGN.md §2)

Dispatch is resolved ONCE (DESIGN.md §4): the generic kernel wrappers
resolve their default ``mode`` from ``REPRO_KERNEL_MODE`` + the
platform on first use, and the serving-attention wrappers resolve the
*attention backend* (``"reference" | "kernel" | "interpret"``) from
``REPRO_ATTENTION_BACKEND`` / ``set_attention_backend()`` the same way
— both log the resolution once and fail loudly, listing the valid
choices, on a bad override. Per-call ``mode=``/``backend=`` arguments
always win over the resolved default.
"""
from __future__ import annotations

import functools
import logging
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_ops
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.paged_decode_attention import (
    paged_decode_attention as _paged_kernel,
)
from repro.kernels.relic_matmul import relic_gemv, relic_matmul
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel

VMEM_BYTES = 16 * 2**20  # v5e per-core VMEM budget

log = logging.getLogger("repro.kernels")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _flight_note(event: str, counter: str, **args) -> None:
    """Mirror a dispatch resolution onto the serving flight recorder
    (DESIGN.md §8) as a counter + instant event on the backend lane.
    Looks the telemetry module up in ``sys.modules`` rather than
    importing it: enabling telemetry requires importing it, so a
    never-imported module means the recorder is off — and the kernel
    layer never pulls ``repro.serve`` in on its own."""
    import sys

    mod = sys.modules.get("repro.serve.telemetry")
    if mod is None:
        return
    tel = mod.get_telemetry()
    if not tel.enabled:
        return
    tel.count(counter)
    tel.tracer.instant(event, "backend", tid=mod.TID_BACKEND, args=args or None)


# ---------------------------------------------------------------------------
# dispatch resolution — once per process, not per call

KERNEL_MODES = ("ref", "kernel", "interpret")
_DEFAULT_MODE: Optional[str] = None  # resolved lazily, cached


def default_kernel_mode() -> str:
    """The ``mode="auto"`` resolution for the generic kernel wrappers:
    ``REPRO_KERNEL_MODE`` if set (bad values fail loudly), else
    ``"kernel"`` on TPU and ``"ref"`` elsewhere. Resolved and logged
    once — callers no longer re-check ``jax.default_backend()`` per
    call."""
    global _DEFAULT_MODE
    if _DEFAULT_MODE is None:
        raw = os.environ.get("REPRO_KERNEL_MODE", "auto")
        if raw not in KERNEL_MODES + ("auto",):
            raise ValueError(
                f"REPRO_KERNEL_MODE={raw!r} is not a valid kernel mode; "
                f"choose one of {('auto',) + KERNEL_MODES}"
            )
        _DEFAULT_MODE = ("kernel" if _on_tpu() else "ref") if raw == "auto" else raw
        log.info(
            "kernel mode resolved once: %s (REPRO_KERNEL_MODE=%s, platform=%s)",
            _DEFAULT_MODE, raw, jax.default_backend(),
        )
        _flight_note(
            "kernel-mode-resolved", "backend.resolutions",
            resolved=_DEFAULT_MODE, source=raw,
        )
    return _DEFAULT_MODE


ATTENTION_BACKENDS = ("reference", "kernel", "interpret")
_ATTN_BACKEND: Optional[str] = None  # resolved lazily, cached


def _validate_backend(name: str, source: str) -> str:
    if name not in ATTENTION_BACKENDS + ("auto",):
        raise ValueError(
            f"{source}={name!r} is not a valid attention backend; "
            f"choose one of {('auto',) + ATTENTION_BACKENDS}"
        )
    return ("kernel" if _on_tpu() else "reference") if name == "auto" else name


def set_attention_backend(name: Optional[str]) -> None:
    """Config-time override of the process-default attention backend
    (``None``/``"auto"`` restores env/platform resolution on next use).
    Jitted step families bind the backend statically at build time (the
    serving engine resolves through here before jitting), so changing
    the default never silently retargets an existing trace."""
    global _ATTN_BACKEND
    if name is not None:
        _validate_backend(name, "backend")  # fail loudly even for "auto"
    _ATTN_BACKEND = None if name in (None, "auto") else name


def resolve_attention_backend(
    backend: Optional[str] = None, mesh=None
) -> str:
    """Per-call override → config override → ``REPRO_ATTENTION_BACKEND``
    → platform default (``"kernel"`` on TPU, ``"reference"`` elsewhere).
    An explicit ``"auto"`` defers to the same default chain as ``None``
    (so the env override is never silently bypassed). Resolution happens
    once and is logged once; bad names fail loudly with the valid
    choices.

    ``mesh`` makes the resolution mesh-aware for sharded serving
    (DESIGN.md §5): under ``shard_map`` the paged kernel runs per-shard
    on local heads, so "kernel" composes with a mesh instead of falling
    back to reference — but un-lowered Pallas cannot run on host
    devices, so on a non-TPU mesh "kernel" resolves to "interpret"
    (the same kernel code, interpreted). TPU meshes keep "kernel"."""
    if backend is not None and backend != "auto":
        resolved = _validate_backend(backend, "backend")
    else:
        global _ATTN_BACKEND
        if _ATTN_BACKEND is None:
            raw = os.environ.get("REPRO_ATTENTION_BACKEND", "auto")
            _ATTN_BACKEND = _validate_backend(raw, "REPRO_ATTENTION_BACKEND")
            log.info(
                "attention backend resolved once: %s (REPRO_ATTENTION_BACKEND=%s, "
                "platform=%s)",
                _ATTN_BACKEND, raw, jax.default_backend(),
            )
            _flight_note(
                "attention-backend-resolved", "backend.resolutions",
                resolved=_ATTN_BACKEND, source=raw,
            )
        resolved = _ATTN_BACKEND
    if mesh is not None and resolved == "kernel" and not _on_tpu():
        log.info(
            "attention backend 'kernel' on a %s mesh → 'interpret' "
            "(Pallas runs per-shard; host devices interpret it)",
            jax.default_backend(),
        )
        _flight_note(
            "attention-backend-fallback", "backend.fallbacks",
            wanted="kernel", resolved="interpret",
            platform=jax.default_backend(),
        )
        return "interpret"
    return resolved


def vmem_working_set(block_bytes: dict[str, int], buffering: int = 2) -> int:
    """Bytes of VMEM a block schedule claims (double-buffered operands)."""
    return sum(buffering * b for b in block_bytes.values())


def check_vmem(block_bytes: dict[str, int], buffering: int = 2) -> None:
    ws = vmem_working_set(block_bytes, buffering)
    if ws > VMEM_BYTES:
        raise ValueError(
            f"block schedule needs {ws/2**20:.1f} MiB VMEM > {VMEM_BYTES/2**20:.0f} MiB: {block_bytes}"
        )


# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "mode"))
def matmul(x, w, *, bm=256, bk=512, bn=256, mode="auto"):
    """Double-buffered block matmul (Relic pair-scheduling on one core)."""
    if mode == "auto":
        mode = default_kernel_mode()
    if mode == "ref":
        return ref_ops.matmul_ref(x, w)
    itemsize = jnp.dtype(x.dtype).itemsize
    check_vmem(
        {
            "x": bm * bk * itemsize,
            "w": bk * bn * itemsize,
            "o": bm * bn * itemsize,
            "acc": bm * bn * 4,
        }
    )
    return relic_matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=mode == "interpret")


@functools.partial(jax.jit, static_argnames=("bk", "bn", "mode"))
def gemv(x, w, *, bk=1024, bn=512, mode="auto"):
    if mode == "auto":
        mode = default_kernel_mode()
    if mode == "ref":
        return ref_ops.matmul_ref(x, w)
    return relic_gemv(x, w, bk=bk, bn=bn, interpret=mode == "interpret")


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "mode"))
def flash_attention(q, k, v, *, causal=True, bq=256, bk=512, mode="auto"):
    if mode == "auto":
        mode = default_kernel_mode()
    if mode == "ref":
        return ref_ops.attention_ref(q, k, v, causal=causal)
    g = q.shape[2] // k.shape[2]
    hd = q.shape[3]
    itemsize = jnp.dtype(q.dtype).itemsize
    check_vmem(
        {
            "q": g * bq * hd * itemsize,
            "k": bk * hd * itemsize,
            "v": bk * hd * itemsize,
            "acc": g * bq * hd * 4,
            "s": g * bq * bk * 4,
        }
    )
    return _flash_kernel(q, k, v, causal=causal, bq=bq, bk=bk, interpret=mode == "interpret")


@functools.partial(jax.jit, static_argnames=("bk", "mode"))
def decode_attention(q, k_cache, v_cache, cache_len, *, bk=512, mode="auto"):
    if mode == "auto":
        mode = default_kernel_mode()
    if mode == "ref":
        return ref_ops.decode_attention_ref(q, k_cache, v_cache, cache_len)
    return _decode_kernel(q, k_cache, v_cache, cache_len, bk=bk, interpret=mode == "interpret")


def paged_attention(
    q, k_pool, v_pool, block_tables, lengths, k_scale=None, v_scale=None, *, mode="auto"
):
    """Block-paged decode/verify attention straight off the block pool.

    q [B,T,H,hd] (T static: 1 = decode, K+1 = speculative verify);
    pools [NB,BS,KV,hd]; ``block_tables`` [B,MB] physical block ids per
    decode row; ``lengths`` [B] committed lengths (query t attends
    positions < lengths + t + 1). int8 pools pass per-vector
    ``k_scale``/``v_scale`` [NB,BS,KV] and dequantize in-kernel. The
    kernel walks the (scalar-prefetched) tables — no dense
    ``gather_block_rows`` materialization; ``"ref"``/``"reference"`` is
    the dense-gather oracle the differential tests compare against.
    ``mode="auto"`` resolves through the ATTENTION registry
    (``REPRO_ATTENTION_BACKEND``/``set_attention_backend``), not the
    generic ``REPRO_KERNEL_MODE`` — this is the serving-attention
    surface. Resolution happens here, OUTSIDE the jit boundary, so a
    later registry change is honored on the next call rather than
    silently replaying the first trace; bad modes fail loudly."""
    if mode == "ref":
        mode = "reference"  # the sibling wrappers' kernel-mode spelling
    mode = resolve_attention_backend(mode)  # validates; auto → the chain
    if mode == "reference":
        mode = "ref"
    return _paged_attention_impl(
        q, k_pool, v_pool, block_tables, lengths, k_scale, v_scale, mode=mode
    )


def paged_attention_partials(
    q, k_pool, v_pool, block_tables, lengths, owned,
    k_scale=None, v_scale=None, *, mode="auto",
):
    """Partials-emitting sibling of ``paged_attention`` for the
    kv-sequence-split serving path: same inputs on a LOCAL pool shard
    plus ``owned`` [B, MB] block ownership, returning the unnormalized
    flash triple ``(m, l, acc)`` for ``collectives.distributed_softmax``
    to combine across the seq mesh axis. Kernel-backend only — the
    reference partials live in ``models/attention.paged_flash_partials``
    (this wrapper is reached with the registry resolved to a kernel
    mode). Called inside shard_map bodies, so unlike ``paged_attention``
    there is no jit wrapper of its own — the enclosing step is the jit
    boundary."""
    if mode == "ref":
        mode = "reference"
    mode = resolve_attention_backend(mode)
    if mode == "reference":
        raise ValueError(
            "paged_attention_partials is the kernel-backend surface; the "
            "reference partials are models/attention.paged_flash_partials"
        )
    itemsize = jnp.dtype(q.dtype).itemsize
    T, hd = q.shape[1], q.shape[3]
    BS = k_pool.shape[1]
    g = q.shape[2] // k_pool.shape[2]
    check_vmem(
        {
            "q": T * g * hd * itemsize,
            "k": BS * hd * jnp.dtype(k_pool.dtype).itemsize,
            "v": BS * hd * jnp.dtype(v_pool.dtype).itemsize,
            "acc": T * g * hd * 4,
            "s": T * g * BS * 4,
        }
    )
    return _paged_kernel(
        q, k_pool, v_pool, block_tables, lengths,
        k_scale=k_scale, v_scale=v_scale, owned=owned, partials=True,
        interpret=mode == "interpret",
    )


@functools.partial(jax.jit, static_argnames=("mode",))
def _paged_attention_impl(
    q, k_pool, v_pool, block_tables, lengths, k_scale, v_scale, *, mode
):
    if mode == "ref":
        return ref_ops.paged_attention_ref(
            q, k_pool, v_pool, block_tables, lengths, k_scale, v_scale
        )
    itemsize = jnp.dtype(q.dtype).itemsize
    T, hd = q.shape[1], q.shape[3]
    BS = k_pool.shape[1]
    g = q.shape[2] // k_pool.shape[2]
    check_vmem(
        {
            "q": T * g * hd * itemsize,
            "k": BS * hd * jnp.dtype(k_pool.dtype).itemsize,
            "v": BS * hd * jnp.dtype(v_pool.dtype).itemsize,
            "acc": T * g * hd * 4,
            "s": T * g * BS * 4,
        }
    )
    return _paged_kernel(
        q, k_pool, v_pool, block_tables, lengths,
        k_scale=k_scale, v_scale=v_scale, interpret=mode == "interpret",
    )


@functools.partial(jax.jit, static_argnames=("chunk", "mode"))
def ssd(xh, a, b, c, dt, *, chunk=128, mode="auto"):
    if mode == "auto":
        mode = default_kernel_mode()
    if mode == "ref":
        return ref_ops.ssd_ref(xh, a, b, c, dt)
    N, hd = b.shape[-1], xh.shape[-1]
    itemsize = jnp.dtype(xh.dtype).itemsize
    check_vmem(
        {
            "x": chunk * hd * itemsize,
            "b": chunk * N * itemsize,
            "c": chunk * N * itemsize,
            "att": chunk * chunk * 4,
            "state": N * hd * 4,
        }
    )
    return _ssd_kernel(xh, a, b, c, dt, chunk=chunk, interpret=mode == "interpret")
