"""jit'd public wrappers for the Pallas kernels.

Each wrapper validates shapes, checks the VMEM working-set budget implied
by the chosen block shapes (double-buffered operands + scratch must fit),
and dispatches kernel vs. pure-jnp reference:

  on TPU            → the Pallas kernel (compiled by Mosaic)
  on CPU, testing   → the kernel in interpret mode (correctness)
  on CPU, dry-run   → the jnp reference (so SPMD partitioning & the
                      roofline read clean HLO; see DESIGN.md §2)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_ops
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.relic_matmul import relic_gemv, relic_matmul
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel

VMEM_BYTES = 16 * 2**20  # v5e per-core VMEM budget


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def vmem_working_set(block_bytes: dict[str, int], buffering: int = 2) -> int:
    """Bytes of VMEM a block schedule claims (double-buffered operands)."""
    return sum(buffering * b for b in block_bytes.values())


def check_vmem(block_bytes: dict[str, int], buffering: int = 2) -> None:
    ws = vmem_working_set(block_bytes, buffering)
    if ws > VMEM_BYTES:
        raise ValueError(
            f"block schedule needs {ws/2**20:.1f} MiB VMEM > {VMEM_BYTES/2**20:.0f} MiB: {block_bytes}"
        )


# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "mode"))
def matmul(x, w, *, bm=256, bk=512, bn=256, mode="auto"):
    """Double-buffered block matmul (Relic pair-scheduling on one core)."""
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref_ops.matmul_ref(x, w)
    itemsize = jnp.dtype(x.dtype).itemsize
    check_vmem(
        {
            "x": bm * bk * itemsize,
            "w": bk * bn * itemsize,
            "o": bm * bn * itemsize,
            "acc": bm * bn * 4,
        }
    )
    return relic_matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=mode == "interpret")


@functools.partial(jax.jit, static_argnames=("bk", "bn", "mode"))
def gemv(x, w, *, bk=1024, bn=512, mode="auto"):
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref_ops.matmul_ref(x, w)
    return relic_gemv(x, w, bk=bk, bn=bn, interpret=mode == "interpret")


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "mode"))
def flash_attention(q, k, v, *, causal=True, bq=256, bk=512, mode="auto"):
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref_ops.attention_ref(q, k, v, causal=causal)
    g = q.shape[2] // k.shape[2]
    hd = q.shape[3]
    itemsize = jnp.dtype(q.dtype).itemsize
    check_vmem(
        {
            "q": g * bq * hd * itemsize,
            "k": bk * hd * itemsize,
            "v": bk * hd * itemsize,
            "acc": g * bq * hd * 4,
            "s": g * bq * bk * 4,
        }
    )
    return _flash_kernel(q, k, v, causal=causal, bq=bq, bk=bk, interpret=mode == "interpret")


@functools.partial(jax.jit, static_argnames=("bk", "mode"))
def decode_attention(q, k_cache, v_cache, cache_len, *, bk=512, mode="auto"):
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref_ops.decode_attention_ref(q, k_cache, v_cache, cache_len)
    return _decode_kernel(q, k_cache, v_cache, cache_len, bk=bk, interpret=mode == "interpret")


@functools.partial(jax.jit, static_argnames=("chunk", "mode"))
def ssd(xh, a, b, c, dt, *, chunk=128, mode="auto"):
    if mode == "ref" or (mode == "auto" and not _on_tpu()):
        return ref_ops.ssd_ref(xh, a, b, c, dt)
    N, hd = b.shape[-1], xh.shape[-1]
    itemsize = jnp.dtype(xh.dtype).itemsize
    check_vmem(
        {
            "x": chunk * hd * itemsize,
            "b": chunk * N * itemsize,
            "c": chunk * N * itemsize,
            "att": chunk * chunk * 4,
            "state": N * hd * 4,
        }
    )
    return _ssd_kernel(xh, a, b, c, dt, chunk=chunk, interpret=mode == "interpret")
