"""Compute/communication co-scheduling: ring-overlapped TP collectives.

The paper's Relic pairs a memory-bound stream with a compute-bound stream
on one SMT core. At cluster scale the analogous idle-resource pair is
ICI (collective) vs MXU (compute): a blocking all-gather before a TP
matmul leaves the MXU idle exactly like a cache miss leaves CPU ports
idle. These ring schedules interleave one ``ppermute`` hop with one
partial matmul per step, so in the compiled HLO the collective-permute
overlaps the dot — the beyond-paper optimization recorded in
EXPERIMENTS.md §Perf.

All functions are *local views* meant to run inside ``shard_map``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


def _ring_perm(p):
    return [(j, (j + 1) % p) for j in range(p)]


def ring_allgather_matmul(x_loc, w_loc, axis_name: str):
    """y_global = all_gather(x, seq) @ w_loc, one ring hop per chunk.

    x_loc [T_l, D] (sequence-sharded), w_loc [D, F_l] → y [P·T_l, F_l].
    Each step multiplies the chunk currently held while the next chunk is
    in flight (the DMA/MXU pair at ICI scale).
    """
    p = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    t_l = x_loc.shape[0]
    acc = jnp.zeros((p * t_l, w_loc.shape[1]), x_loc.dtype)

    # unrolled python loop: lets XLA schedule permute i+1 against dot i.
    # after i ring hops (j → j+1) device idx holds chunk (idx - i) % p.
    x_cur = x_loc
    for i in range(p):
        if i != p - 1:
            x_nxt = lax.ppermute(x_cur, axis_name, _ring_perm(p))  # comm stream
        part = jnp.dot(x_cur, w_loc)  # compute stream
        src = (idx - i) % p
        acc = lax.dynamic_update_slice(acc, part.astype(acc.dtype), (src * t_l, 0))
        if i != p - 1:
            x_cur = x_nxt
    return acc


def matmul_reducescatter(h_loc, w_loc, axis_name: str):
    """y_loc = reduce_scatter(h_global_chunks @ w_loc) over `axis_name`.

    h_loc [T, F_l] (full sequence, hidden-sharded), w_loc [F_l, D] →
    y [T/P, D]: each step computes the partial for one peer's sequence
    chunk and passes the accumulating partial around the ring.
    """
    p = compat.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    t = h_loc.shape[0]
    t_l = t // p
    d = w_loc.shape[1]

    acc = jnp.zeros((t_l, d), jnp.float32)
    for i in range(p):
        # at step i every device contributes its partial for the chunk
        # that will land on its owner after the remaining p-1-i hops
        src = (idx + p - 1 - i) % p
        chunk = lax.dynamic_slice(h_loc, (src * t_l, 0), (t_l, h_loc.shape[1]))
        part = jnp.dot(chunk, w_loc, preferred_element_type=jnp.float32)
        acc = acc + part
        if i != p - 1:
            acc = lax.ppermute(acc, axis_name, _ring_perm(p))
    return acc.astype(h_loc.dtype)


def distributed_softmax(m_loc, l_loc, acc_loc, axis_name: str):
    """Combine per-rank flash-decode partials over ``axis_name``.

    Each rank holds a partial softmax over its local slice of the KV
    sequence for the SAME query/head set, in the usual flash-attention
    running form:

        m_loc   [...]      local running max of the logits
        l_loc   [...]      local sum of exp(logit - m_loc)
        acc_loc [..., d]   local sum of exp(logit - m_loc) · v

    The exact global softmax follows from rescaling each rank's partial
    to the global max m = max_r m_r:

        l   = Σ_r l_r · exp(m_r − m)
        acc = Σ_r acc_r · exp(m_r − m)
        out = acc / l

    because exp(logit − m) = exp(logit − m_r) · exp(m_r − m) for every
    logit that rank r saw. Returns the combined ``out [..., d]``.

    This is the kv-sequence-split combine (``ShardingRules`` 'kv_seq',
    DESIGN.md §5): it runs on the serving hot path whenever the paged
    pool is partitioned over a ``"seq"`` mesh axis. The head-partitioned
    path never calls it — softmax is per-head, so a head shard completes
    its softmax locally.

    Empty shards: a rank whose slice holds zero valid keys carries
    ``m_loc = -inf`` (or the ``-1e30`` mask sentinel the masked-softmax
    paths use) with ``l_loc = 0``. ``exp(m_loc - m)`` would be
    ``exp(-inf - -inf) = NaN`` when every rank is empty, and even a
    single empty rank must not poison the psum — so ``scale`` is forced
    to exactly 0 on empty shards, and the all-ranks-empty case returns
    exact zeros (0-acc over the tiny-clamped denominator), never NaN.
    """
    empty = m_loc <= jnp.asarray(-1e30, m_loc.dtype)  # -inf or mask sentinel
    m = lax.pmax(m_loc, axis_name)
    scale = jnp.where(empty, 0.0, jnp.exp(m_loc - m))
    l = lax.psum(l_loc * scale, axis_name)
    acc = lax.psum(acc_loc * scale[..., None], axis_name)
    return acc / jnp.maximum(l, jnp.finfo(acc.dtype).tiny)[..., None]


def sp_swiglu(x, w1, w3, w2, rules):
    """Sequence-parallel SwiGLU with ring-overlapped TP collectives.

    x [B, S, D] with S sharded over 'model'; w1/w3 [D, F], w2 [F, D] with
    F sharded over 'model'. Equivalent to swiglu() but the all-gather of
    x and the reduce-scatter of the output are software-pipelined against
    the matmuls.
    """
    mesh = rules.mesh
    batch_axes = rules.table["batch"]

    def body(x_loc, w1_loc, w3_loc, w2_loc):
        b, s_l, d = x_loc.shape
        x2 = x_loc.reshape(b * s_l, d)
        h1 = ring_allgather_matmul(x2, w1_loc, "model")  # [B·S, F_l]
        h3 = ring_allgather_matmul(x2, w3_loc, "model")
        h = jax.nn.silu(h1) * h3
        y = matmul_reducescatter(h, w2_loc, "model")  # [B·S/P, D]
        s_out = s_l  # P·s_l / P
        return y.reshape(b, s_out, d)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, "model", None),
            P(None, "model"),
            P(None, "model"),
            P("model", None),
        ),
        out_specs=P(batch_axes, "model", None),
        check_vma=False,
    )
    return fn(x, w1, w3, w2)
