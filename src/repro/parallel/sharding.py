"""Logical-axis sharding rules with divisibility fallback.

Every parameter / activation carries a tuple of *logical* axis names.
``ShardingRules`` maps logical names onto mesh axes, dropping any mapping
whose dimension does not divide evenly by the mesh-axis size. Each dropped
mapping is recorded — the adviser (core/adviser.py) treats fallbacks exactly
like the paper treats "kernel too fine-grained for this scheduling strategy"
and picks the next strategy in the band (DESIGN.md §6.1).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

MeshAxes = Union[None, str, Tuple[str, ...]]


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


class ShardingRules:
    """cfg + mesh → PartitionSpecs for logical-axis-annotated arrays."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        self.mesh = mesh
        self.cfg = cfg
        self.fallbacks: list[str] = []
        has_pod = "pod" in mesh.shape
        batch_axes: MeshAxes = ("pod", "data") if has_pod else ("data",)
        fsdp = cfg.param_sharding == "fsdp"

        model = mesh.shape.get("model", 1)
        heads_ok = cfg.n_heads and cfg.n_heads % model == 0
        kv_ok = cfg.n_kv_heads and cfg.n_kv_heads % model == 0
        self.kv_heads_ok = bool(kv_ok)

        self.table: dict[str, MeshAxes] = {
            # parameter axes
            "layers": None,
            "groups": None,
            "embed": ("data",) if fsdp else None,
            "mlp": "model",
            "heads": "model" if heads_ok else None,
            "kv_heads": "model" if kv_ok else None,
            # q heads within a kv group always travel with their group's kv
            # head (serving TP shards contiguous head blocks), never alone
            "q_heads_per_group": None,
            "head_dim": None,
            "qdim": "model",  # flattened h·hd projection dim (attn_flat_tp)
            "vocab": "model",
            "experts": "model",
            "expert_mlp": ("data",) if fsdp else None,  # FSDP axis on expert F
            "ssm_heads": "model",
            "ssm_inner": "model",
            "state": None,
            "conv": None,
            # activation axes
            "batch": batch_axes,
            "seq": None,
            # sequence-parallel fallback: queries over 'model' when heads
            # cannot shard (DESIGN.md §6.1)
            "seq_sp": "model" if not heads_ok else None,
            # decode KV-cache sequence axis: shard over 'model' when the
            # kv-head axis cannot (flash-decode partial-softmax combine)
            "kv_seq": None if kv_ok else "model",
            # paged-pool block dim over the serving 'seq' mesh axis
            # (kv-sequence split; per-rank flash partials combined by
            # collectives.distributed_softmax — DESIGN.md §5)
            "kv_blocks": "seq",
            "tokens_ep": (batch_axes + ("model",))
            if isinstance(batch_axes, tuple)
            else (batch_axes, "model"),
        }

    # ------------------------------------------------------------------
    def spec(
        self, axes: Sequence[Optional[str]], shape: Sequence[int]
    ) -> P:
        """PartitionSpec for an array with the given logical axes + shape.

        Any logical→mesh mapping that does not divide the dimension evenly
        is dropped (recorded in ``self.fallbacks``). Mesh axes already used
        by an earlier dimension are also dropped (a mesh axis may shard at
        most one dim).
        """
        assert len(axes) == len(shape), (axes, shape)
        used: set[str] = set()
        out = []
        for name, dim in zip(axes, shape):
            mesh_axes = self.table.get(name) if name else None
            if mesh_axes is None:
                out.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            # Mesh axes the mesh does not define are not candidates at all
            # (e.g. 'data' on a serving-only ('model',) mesh) — skipping them
            # is not a fallback event. Axes already consumed by an EARLIER
            # dimension of this array are dropped and recorded under the
            # logical name of the dimension being dropped (the later one),
            # then divisibility is checked progressively.
            keep = []
            for a in mesh_axes:
                if a not in self.mesh.shape:
                    continue
                if a in used:
                    self.fallbacks.append(
                        f"{name}:{dim} mesh axis {a} already used by an "
                        f"earlier dim; dropped {a}"
                    )
                    continue
                keep.append(a)
            cand = tuple(keep)
            while cand and dim % _axis_size(self.mesh, cand) != 0:
                dropped = cand[-1]
                cand = cand[:-1]
                self.fallbacks.append(
                    f"{name}:{dim} ∤ mesh{dropped}; dropped {dropped}"
                )
            if not cand:
                out.append(None)
                continue
            used.update(cand)
            out.append(cand[0] if len(cand) == 1 else cand)
        return P(*out)

    def sharding(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]):
        """with_sharding_constraint by logical axes (inside jit)."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(axes, x.shape))
        )

    # ------------------------------------------------------------------
    def tp_view(self) -> "ShardingRules":
        """Rules with the FSDP ('data') parameter axes dropped — the
        compute-time layout of ZeRO-2: storage stays FSDP-sharded, the
        train step gathers ONCE per step (EXPERIMENTS.md §Perf #phi3)."""
        import copy

        clone = copy.copy(self)
        clone.table = dict(self.table)
        for k in ("embed", "expert_mlp"):
            clone.table[k] = None
        clone.fallbacks = self.fallbacks
        return clone

    # ------------------------------------------------------------------
    def tree_shardings(self, params, axes_tree):
        """Shardings for a (params, logical-axes) tree pair."""

        def one(p, ax):
            shape = p.shape if hasattr(p, "shape") else ()
            return self.sharding(ax, shape)

        return jax.tree.map(
            one, params, axes_tree, is_leaf=lambda x: isinstance(x, tuple)
        )


def tree_shardings(mesh: Mesh, cfg: ModelConfig, params, axes_tree):
    return ShardingRules(mesh, cfg).tree_shardings(params, axes_tree)


def paged_pool_specs(
    axis: Optional[str] = "model",
    seq_axis: Optional[str] = None,
    *,
    quantized: bool = False,
) -> dict:
    """PartitionSpecs for the paged KV pool leaves ``[L, NB, BS, KV, hd]``.

    The serving mesh shards at most two pool dimensions: the kv-head dim
    (3) over ``axis`` — PR 7's head-partitioned tensor parallelism,
    bitwise-preserving — and the block dim (1) over ``seq_axis`` — the
    kv-sequence split, where each rank holds a contiguous range of
    physical blocks, attends over only the positions it owns, and the
    per-rank flash partials are combined by
    ``collectives.distributed_softmax`` (rounding-level, DESIGN.md §5).
    Either axis may be ``None``; quantized pools carry per-(block, row)
    scale leaves that shard the same way (minus the head_dim axis).
    """
    kv = P(None, seq_axis, None, axis, None)
    specs = {"k": kv, "v": kv}
    if quantized:
        sc = P(None, seq_axis, None, axis)
        specs["k_scale"] = sc
        specs["v_scale"] = sc
    return specs
