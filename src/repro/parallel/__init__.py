from repro.parallel.sharding import ShardingRules  # noqa: F401
