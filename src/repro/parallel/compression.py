"""Cross-pod gradient compression with error feedback.

At 2+ pods the inter-pod hop is the slow link (DCN / optical ICI): the
gradient all-reduce over the 'pod' axis moves full fp32 tensors through
it every step. We compress that hop only: int8 quantization with a
per-tensor scale and an error-feedback residual so the quantization
noise is re-injected next step (Seide et al. / 1-bit-SGD lineage;
convergence-safe for smooth objectives).

Summing int8 payloads from ≤128 pods fits int16 exactly, so the reduce
is lossless post-quantization; the 4× byte reduction shows up directly
in the dry-run's collective-bytes table (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def _quantize(g, scale):
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q.astype(jnp.int8), g - q * scale  # (payload, residual)


def compressed_psum(g, axis_name: str, err):
    """all-reduce g over `axis_name` in int8; returns (mean_g, new_err).

    err is the error-feedback residual from the previous step (same shape
    as g; zeros initially). Call inside shard_map/pjit with `axis_name`
    bound.
    """
    n = compat.axis_size(axis_name)
    g_fb = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(g_fb)) / 127.0, 1e-12)
    # share one scale so the reduced payload dequantizes exactly
    scale = lax.pmax(scale, axis_name)
    q, new_err = _quantize(g_fb, scale)
    total = lax.psum(q.astype(jnp.int16), axis_name)  # ≤127·n fits int16
    return total.astype(jnp.float32) * scale / n, new_err


def compressed_grad_tree(grads, axis_name: str, err_tree):
    """Tree-mapped compressed_psum; returns (mean grads, new residuals)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    outs = [compressed_psum(g, axis_name, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, new_e
