"""2-stage GPipe over the 'pod' axis (inter-pod pipeline parallelism).

The multi-pod mesh's slow hop is pod↔pod: pure data parallelism pays a
full cross-pod gradient all-reduce per step, while pipeline parallelism
moves one activation handoff per microbatch through the slow link — the
standard placement at 1000+ nodes. This module stages a scanned layer
stack across the pod axis.

Schedule (2 stages, M microbatches, M+1 ticks):

  tick t : stage0 runs microbatch t (t < M);
           stage1 runs the activation received at tick t-1 (t ≥ 1);
           one collective_permute hands stage0's output forward.

Bubble fraction = 1/(M+1). The implementation is family-agnostic: it
wraps any ``layer_fn(stage_params, x) → x``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


def pipelined_apply(layer_fn, stage_params, x, *, mesh, n_micro: int, axis: str = "pod"):
    """Run a 2-stage pipeline over `axis`; returns layer_fn∘layer_fn (x).

    stage_params: stacked leaves [n_stages, ...], sharded over `axis`
                  (stage i's sub-stack at index i).
    x: [B, ...], microbatched along B into n_micro chunks.
    """
    n_stages = mesh.shape[axis]
    assert n_stages == 2, "demo pipeline is 2-stage (pod axis)"
    b = x.shape[0]
    assert b % n_micro == 0
    mb = b // n_micro

    def body(params_loc, x_loc):
        params_stage = jax.tree.map(lambda p: p[0], params_loc)
        stage = lax.axis_index(axis)
        micro = x_loc.reshape((n_micro, mb) + x_loc.shape[1:])
        fwd = [(0, 1)]  # stage0 → stage1 handoff

        def step(inflight, t):
            t_clamped = jnp.minimum(t, n_micro - 1)
            mb_t = lax.dynamic_index_in_dim(micro, t_clamped, 0, keepdims=False)
            x_in = jnp.where(stage == 0, mb_t, inflight)
            y = layer_fn(params_stage, x_in)
            nxt = lax.ppermute(y, axis, fwd)  # stage1's copy drops off ring
            return nxt, y

        init = jnp.zeros((mb,) + x_loc.shape[1:], x_loc.dtype)
        _, ys = lax.scan(step, init, jnp.arange(n_micro + 1))
        # on stage 1, ys[1:] are the finished microbatches; replicate back
        outs = ys[1:].reshape((b,) + x_loc.shape[1:])
        outs_from_1 = lax.ppermute(outs, axis, [(1, 0)])
        return jnp.where(stage == 1, outs, outs_from_1)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, x)
