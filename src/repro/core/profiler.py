"""Hotspot detection from compiled XLA artifacts (perf + LBR analogue).

``profile_step`` lowers+compiles a jitted step (optionally under a mesh)
and packages FLOPs/bytes/collective-bytes plus roofline terms — the
"sampled profile in JSON" the paper's wrapper tool feeds the LLM.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Optional

import jax

from repro.core import hlo_analysis, hlo_cost
from repro.core.overlap_model import HwModel


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        t = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(t, key=t.get)

    @property
    def bound_s(self) -> float:
        """Roofline-optimal step time = max of the three terms (perfect
        overlap of the other two)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def serial_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s


@dataclass
class ProfiledStep:
    name: str
    flops: float  # per device
    bytes_accessed: float  # per device
    collectives: hlo_analysis.CollectiveStats
    ops: hlo_analysis.OpStats
    memory_stats: Any
    terms: RooflineTerms
    hlo_size: int = 0
    compiled: Any = None

    def hotspots(self, hw: HwModel | None = None, top=10):
        hw = hw or HwModel()
        return self.ops.hotspots(hw.peak_flops, hw.hbm_bw, top)

    def report(self) -> dict:
        return {
            "name": self.name,
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.collectives.bytes_total,
            "roofline": {
                "compute_s": self.terms.compute_s,
                "memory_s": self.terms.memory_s,
                "collective_s": self.terms.collective_s,
                "dominant": self.terms.dominant,
            },
            "collectives": dict(self.collectives.counts),
            "hotspots": [
                {"op": op, "modeled_s": t} for op, t in self.hotspots()
            ],
        }

    def to_json(self) -> str:
        return json.dumps(self.report(), indent=2, default=float)


def profile_step(
    fn,
    *abstract_args,
    name: str = "step",
    mesh=None,
    in_shardings=None,
    out_shardings=None,
    donate_argnums=(),
    hw: HwModel | None = None,
    static_argnames=None,
    keep_compiled: bool = False,
    **abstract_kwargs,
) -> ProfiledStep:
    """Lower + compile; derive per-device roofline terms (DESIGN.md §7)."""
    hw = hw or HwModel()
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    if donate_argnums:
        kw["donate_argnums"] = donate_argnums
    if static_argnames:
        kw["static_argnames"] = static_argnames
    jitted = jax.jit(fn, **kw)
    if mesh is not None:
        with mesh:
            lowered = jitted.lower(*abstract_args, **abstract_kwargs)
            compiled = lowered.compile()
    else:
        lowered = jitted.lower(*abstract_args, **abstract_kwargs)
        compiled = lowered.compile()

    text = compiled.as_text()
    tc = hlo_cost.analyze(text)  # trip-count-aware costs
    flops = tc.flops
    nbytes = tc.bytes
    colls = hlo_analysis.CollectiveStats()
    colls.counts.update({k: int(v) for k, v in tc.collective_counts.items()})
    colls.bytes_by_op.update(tc.collective_by_op)
    colls.bytes_total = tc.collective_bytes
    ops = hlo_analysis.op_stats(text)
    mem = compiled.memory_analysis()

    terms = RooflineTerms(
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=colls.bytes_total / hw.ici_bw,
    )
    return ProfiledStep(
        name=name,
        flops=flops,
        bytes_accessed=nbytes,
        collectives=colls,
        ops=ops,
        memory_stats=mem,
        terms=terms,
        hlo_size=len(text),
        compiled=compiled if keep_compiled else None,
    )
