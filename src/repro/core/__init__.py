"""Aira core: the paper's contribution as a composable JAX module."""
from repro.core.adviser import AdviceReport, Aira, Region, Workload  # noqa: F401
from repro.core.overlap_model import (  # noqa: F401
    HwModel,
    Microtask,
    OverlapModel,
    SchedulePrediction,
    gate,
)
from repro.core.profiler import ProfiledStep, RooflineTerms, profile_step  # noqa: F401
from repro.core.relic import RelicSchedule, choose_schedule, relic_pfor  # noqa: F401
