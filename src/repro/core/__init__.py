"""Aira core: the paper's contribution as a composable JAX module."""
from repro.core.adviser import AdviceReport, Aira, Region, RegionDecision, Workload  # noqa: F401
from repro.core.overlap_model import (  # noqa: F401
    HwModel,
    Microtask,
    OverlapModel,
    SchedulePrediction,
    gate,
)
from repro.core.plan import (  # noqa: F401
    RegionPlan,
    SuiteEntry,
    advise_suite,
    clear_plan_cache,
    plan_cache_stats,
    plan_for,
    plan_for_region,
)
from repro.core.profiler import ProfiledStep, RooflineTerms, profile_step  # noqa: F401
from repro.core.relic import RelicSchedule, choose_schedule, relic_pfor  # noqa: F401
from repro.core.tools import (  # noqa: F401
    DEFAULT_TOOLS,
    AdviserPolicy,
    AdviserTool,
    RecordingPolicy,
    ReplayPolicy,
    SpecPolicy,
    StageResult,
    ToolContext,
    ToolPipeline,
)
