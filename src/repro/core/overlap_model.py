"""SMT-aware dual-stream performance simulator (the Sniper analogue).

The paper extends Sniper to predict whether co-scheduling a pair of
fine-grained task streams onto one SMT core is profitable, and *gates*
parallelization on that prediction (§V — the Fraud benchmark is rejected
here). This module is the analytical equivalent, with the resource
physics that actually drive the paper's observations:

* A single thread of a latency-critical kernel leaves resources idle in
  two ways: **dependent-access stalls** (pointer chasing — the chain of
  ``chain`` serialized memory latencies per task) and **ILP slack**
  (``ilp_eff`` < 1: one thread cannot fill all issue ports).
* Co-scheduling a second stream hides chain stalls and fills ports, but
  *shared* resources (FU throughput, DRAM/HBM bandwidth) are not
  duplicated, and the pair contends (``contention``).

Per-schedule wall-time for n microtasks (c = FLOP time at full issue,
c_s = c/ilp_eff single-thread, m_lat = chain·mem_latency, m_bw =
bytes/bandwidth):

  serial : n·(c_s + m_lat + m_bw)
  smt2   : max( (n/2)·(c_s+m_lat+m_bw)·(1+φ),   ← per-stream chain
                n·c·(1+φ),                       ← shared issue ports
                n·m_bw )                         ← shared bandwidth
           + n·o_task + o_region
  smp2   : max( (n/2)·(c_s+m_lat+m_bw), n·m_bw )
           + n·o_task·xcore_penalty + o_region_smp

The granularity band of the paper's Figs. 1–2 falls out of the o-terms
(below the band dispatch dominates) and of φ (above it two full cores
beat one contended core).

TPU translation (DESIGN.md §2): the stream pair is DMA vs MXU on one
TensorCore; `serial` is the unpipelined kernel, `smt2` the double-
buffered Pallas schedule, `smp2` splitting across two cores; `chain`
models dependent HBM gathers (the linked-structure traversals of the
paper's benchmarks).

Runtime presets: Relic's dispatch is ~100 ns (the paper's enabling
observation); an OpenMP-style runtime pays ~5× that per task plus a
microsecond-scale region fork/join.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HwModel:
    peak_flops: float = 197e12  # bf16 MXU, per chip
    vpu_flops: float = 4e12  # vector/scalar math (gather-heavy regions)
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    mem_latency: float = 400e-9  # dependent random-access latency
    ilp_eff: float = 0.88  # single-stream issue-port utilization
    contention: float = 0.08  # residual overhead on shared-resource floors
    pair_contention: float = 0.55  # per-stream slowdown when co-scheduled
    bw_contention: float = 0.15  # interleaved-stream bandwidth loss
    mlp_eff: float = 0.40  # extra memory-level parallelism the 2nd stream
    # can actually extract (latency floor = m_lat/(1+mlp_eff))
    xcore_penalty: float = 3.0  # cross-core task handoff vs same-core
    smp_setup: float = 1.5e-6  # waking/pinning the second physical core
    fill_depth: int = 2  # pipeline fill (double buffering)


@dataclass(frozen=True)
class RuntimeModel:
    name: str
    o_task: float  # per-microtask dispatch
    o_region: float  # parallel-region entry/exit (fork/join)


RELIC = RuntimeModel("relic", o_task=100e-9, o_region=50e-9)
OPENMP = RuntimeModel("openmp", o_task=500e-9, o_region=800e-9)

# The paper's evaluation machine (i7-12700 P-core, DDR5): used by the
# bench_suite figure reproduction. The default HwModel above is TPU v5e —
# used when the adviser prices LM-scale kernels.
CPU_HW = HwModel(
    peak_flops=50e9,  # one P-core, AVX2 FMA
    vpu_flops=6e9,  # scalar/branchy pointer-chasing code
    hbm_bw=30e9,  # single-core DRAM streaming
    ici_bw=0.0,
    mem_latency=80e-9,  # DDR5 random access
    ilp_eff=0.88,
    contention=0.08,
    xcore_penalty=3.0,
)


@dataclass(frozen=True)
class Microtask:
    """One fine-grained task: FLOPs, streamed bytes, dependent-access chain."""

    flops: float
    bytes: float
    chain: int = 0  # serialized dependent memory accesses (tree hops)
    vector: bool = False  # VPU-bound (gather/pointer-chase) vs MXU


@dataclass
class SchedulePrediction:
    serial: float
    smt2: float
    smp2: float

    @property
    def best(self) -> str:
        t = {"serial": self.serial, "smt2": self.smt2, "smp2": self.smp2}
        return min(t, key=t.get)

    def gain(self, schedule: str) -> float:
        """Relative speedup of `schedule` over serial (paper Figs. 1–4)."""
        t = {"smt2": self.smt2, "smp2": self.smp2, "serial": self.serial}[schedule]
        return self.serial / t - 1.0


class OverlapModel:
    def __init__(self, hw: HwModel | None = None, runtime: RuntimeModel = RELIC):
        self.hw = hw or HwModel()
        self.runtime = runtime

    # ------------------------------------------------------------------
    def _components(self, task: Microtask):
        hw = self.hw
        c = task.flops / (hw.vpu_flops if task.vector else hw.peak_flops)
        c_s = c / hw.ilp_eff
        m_lat = task.chain * hw.mem_latency
        m_bw = task.bytes / hw.hbm_bw
        return c, c_s, m_lat, m_bw

    def predict(
        self, task: Microtask, n_tasks: int, runtime: RuntimeModel | None = None
    ) -> SchedulePrediction:
        """Wall time of each schedule = max over binding resource bounds
        (per-stream chain, shared issue ports, shared bandwidth, finite
        memory-level parallelism) + dispatch overheads (docstring above)."""
        hw, rt = self.hw, runtime or self.runtime
        n = n_tasks
        c, c_s, m_lat, m_bw = self._components(task)
        per = c_s + m_lat + m_bw

        serial = n * per

        fill = hw.fill_depth * min(c_s, m_lat + m_bw)  # pipeline warmup
        smt2 = (
            max(
                (n / 2) * per * (1 + hw.pair_contention),  # per-stream chain
                n * c * (1 + hw.contention),  # shared issue ports
                n * m_bw * (1 + hw.bw_contention),  # shared bandwidth
                n * m_lat / (1 + hw.mlp_eff) * (1 + hw.contention),  # MLP cap
            )
            + n * rt.o_task
            + rt.o_region
            + fill
        )
        smp2 = (
            max(math.ceil(n / 2) * per, n * m_bw)
            + n * rt.o_task * hw.xcore_penalty
            + rt.o_region * hw.xcore_penalty
            + hw.smp_setup
        )
        return SchedulePrediction(serial=serial, smt2=smt2, smp2=smp2)

    # ------------------------------------------------------------------
    def granularity_sweep(
        self, base: Microtask, total_items: int, grans, runtime=None
    ):
        """Speedup-vs-granularity curves (reproduces paper Figs. 1–2).

        granularity g groups g items into one microtask: n = total/g tasks
        each g× the base cost. (Grouping amortizes dispatch but does not
        change resource totals — exactly the paper's sweep.)
        """
        rows = []
        for g in grans:
            task = Microtask(
                flops=base.flops * g,
                bytes=base.bytes * g,
                chain=base.chain * g,
                vector=base.vector,
            )
            n = max(1, total_items // g)
            p = self.predict(task, n, runtime)
            rows.append(
                {
                    "granularity": g,
                    "smt_gain": p.gain("smt2"),
                    "smp_gain": p.gain("smp2"),
                    "serial_us": p.serial * 1e6,
                }
            )
        return rows

    def profitable_band(self, base: Microtask, total_items: int):
        """Granularity range where smt2 beats BOTH serial and smp2 —
        the paper's primary target (§IV)."""
        lo, hi = None, None
        g = 1
        while g <= total_items:
            task = Microtask(base.flops * g, base.bytes * g, base.chain * g, base.vector)
            p = self.predict(task, max(1, total_items // g))
            if p.smt2 < p.serial and p.smt2 <= p.smp2:
                lo = g if lo is None else lo
                hi = g
            g *= 2
        return lo, hi


def gate(prediction: SchedulePrediction, threshold: float = 0.02) -> tuple[bool, str]:
    """The Sniper gate: accept only if predicted smt2 gain > threshold."""
    g = prediction.gain("smt2")
    if g > threshold:
        return True, f"accepted: predicted +{g*100:.1f}%"
    return False, f"rejected: predicted {g*100:+.1f}% ≤ {threshold*100:.0f}% gate"
