"""Dependence analysis: static (jaxpr) + dynamic (recorded access traces).

The paper checks every annotated region twice before parallelizing:
BOLT-based *static* dependence analysis over the binary, and *dynamic*
memory-access conflict detection over DynamoRIO load/store traces. The
JAX translation:

static  — walk the region's jaxpr with *provenance tracking*: a scatter
          into an argument-derived array is the analogue of a shared-
          memory write (demands a dynamic trace); a scatter into a
          locally-created buffer is a private stack write (safe). Loop-
          carried values (scan/while carries) are recorded — they
          serialize *within* a work item but do not block across-item
          parallelism for a pure per-item region.
dynamic — replay the region's recorded gather/scatter index sets under
          the proposed task partition and reject on any cross-task
          write↔read/write overlap (``check_conflicts``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

SCATTER_PRIMS = {
    "scatter",
    "scatter-add",
    "scatter_add",
    "scatter_mul",
    "scatter_min",
    "scatter_max",
    "scatter_apply",
    "dynamic_update_slice",
}
GATHER_PRIMS = {"gather", "dynamic_slice", "take", "take_along_axis"}


@dataclass
class StaticReport:
    n_eqns: int = 0
    gathers: int = 0
    scatters: int = 0
    shared_scatters: int = 0  # writes into argument-derived arrays
    loops: int = 0
    loop_carried: int = 0
    prims: dict = field(default_factory=dict)

    @property
    def trivially_parallel(self) -> bool:
        """No writes into shared (argument-derived) state → the region can
        be partitioned across items without a dynamic trace."""
        return self.shared_scatters == 0

    def summary(self) -> str:
        return (
            f"eqns={self.n_eqns} gathers={self.gathers} "
            f"scatters={self.scatters} shared_writes={self.shared_scatters} "
            f"loops={self.loops} carried={self.loop_carried} "
            f"parallel={'yes' if self.trivially_parallel else 'needs-trace'}"
        )


def _sub_jaxprs(eqn):
    """(closed_jaxpr, outer_invars_for_body) pairs for control-flow prims."""
    name = eqn.primitive.name
    p = eqn.params
    out = []
    if name == "scan":
        nc, nk = p.get("num_consts", 0), p.get("num_carry", 0)
        body = p["jaxpr"]
        out.append((body, list(eqn.invars)))
    elif name == "while":
        # const/carry split differs between cond and body: be conservative
        out.append((p["body_jaxpr"], None))
        out.append((p["cond_jaxpr"], None))
    elif name == "cond":
        for br in p["branches"]:
            out.append((br, list(eqn.invars)[1:]))
    elif "jaxpr" in p and hasattr(p["jaxpr"], "jaxpr"):
        out.append((p["jaxpr"], list(eqn.invars)))
    return out


# primitives whose result would ALIAS operand storage in the C original
# (pointer into the structure / in-place update); everything else copies
_ALIAS_OP0 = {
    "reshape", "transpose", "squeeze", "rev", "slice", "broadcast_in_dim",
    "dynamic_slice", "gather",
} | SCATTER_PRIMS
_ALIAS_ANY = {"select_n"}


def _walk(jaxpr, shared_vars: set, rep: StaticReport):
    """shared_vars: vars that alias region-argument/closure storage. A
    scatter into aliased storage is a shared-memory write (needs a
    dynamic trace); a scatter into a locally-allocated buffer (zeros,
    arithmetic results) is a private stack write."""
    shared = set(shared_vars)

    def is_shared(v):
        return (not hasattr(v, "val")) and v in shared

    for eqn in jaxpr.eqns:
        rep.n_eqns += 1
        name = eqn.primitive.name
        rep.prims[name] = rep.prims.get(name, 0) + 1
        if name in GATHER_PRIMS:
            rep.gathers += 1
        if name in SCATTER_PRIMS:
            rep.scatters += 1
            if eqn.invars and is_shared(eqn.invars[0]):
                rep.shared_scatters += 1
        if name in ("scan", "while"):
            rep.loops += 1
            rep.loop_carried += eqn.params.get("num_carry", len(eqn.outvars))
        for closed, outer_vars in _sub_jaxprs(eqn):
            inner = closed.jaxpr
            if outer_vars is None:  # conservative: everything shared
                inner_shared = set(inner.invars)
            else:
                inner_shared = set()
                for iv, ov in zip(inner.invars, outer_vars[: len(inner.invars)]):
                    if is_shared(ov):
                        inner_shared.add(iv)
            _walk(inner, inner_shared, rep)
        # alias propagation
        if name in _ALIAS_OP0 and eqn.invars and is_shared(eqn.invars[0]):
            shared.update(eqn.outvars)
        elif name in _ALIAS_ANY and any(is_shared(v) for v in eqn.invars):
            shared.update(eqn.outvars)


def static_deps(fn, *sample_args, **kw) -> StaticReport:
    closed = jax.make_jaxpr(fn, **kw)(*sample_args)
    rep = StaticReport()
    shared = set(closed.jaxpr.invars) | set(closed.jaxpr.constvars)
    _walk(closed.jaxpr, shared, rep)
    return rep


# ---------------------------------------------------------------------------
# dynamic traces


@dataclass
class MemoryTrace:
    """Recorded dynamic accesses of one region execution (per work item).

    reads/writes: list over work items of integer index arrays — the
    DynamoRIO load/store trace analogue, in element-index space.
    """

    reads: list
    writes: list

    @property
    def n_items(self) -> int:
        return len(self.reads)


def check_conflicts(trace: MemoryTrace, n_tasks: int) -> tuple[bool, str]:
    """Partition work items round-robin into n_tasks; conflict iff some
    task writes an index another task reads or writes."""
    n = trace.n_items
    writes_by_task = [set() for _ in range(n_tasks)]
    reads_by_task = [set() for _ in range(n_tasks)]
    for i in range(n):
        t = i % n_tasks
        writes_by_task[t].update(np.asarray(trace.writes[i]).ravel().tolist())
        reads_by_task[t].update(np.asarray(trace.reads[i]).ravel().tolist())
    for t in range(n_tasks):
        for u in range(n_tasks):
            if t == u:
                continue
            inter = writes_by_task[t] & (reads_by_task[u] | writes_by_task[u])
            if inter:
                return True, f"W/R conflict tasks {t}↔{u} on {len(inter)} addresses"
    return False, "no cross-task conflicts"
