"""The execution-plan layer: cached, reusable results of an advisory run.

An advisory run used to end at a throwaway closure — every caller
(benchmark figure, example, test, serving engine) re-derived and
re-traced the same restructured region. ``RegionPlan`` makes the
accepted schedule + its jit-compiled ``parallel_fn`` a first-class,
cached artifact (DESIGN.md §1):

* Plans are cached by ``PlanKey`` = (region signature, granularity,
  n_streams, combine, HwModel). The region signature is the region's
  *name* plus the item pytree's (shape, dtype) structure — the paper's
  region→source mapping — so re-advising the same region returns the
  same plan and re-executing it hits jax's jit cache (no retrace).
* ``advise_suite()`` batch-advises every registered benchmark through
  the tool pipeline and returns per-benchmark plans; the serving engine
  accepts a plan for its decode step the same way.

Caveat that follows from keying on the signature rather than on the
function object: keys include a head/tail content fingerprint of the
items, but two *different* programs advised under one region name,
item signature, and identical item boundary values still collide. Use
distinct region names (as the paper's region→source mapping does) or
``clear_plan_cache()``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.core.overlap_model import HwModel
from repro.core.relic import RelicSchedule, relic_pfor


@dataclass(frozen=True)
class PlanKey:
    region: str
    items_sig: tuple  # (treedef_repr, ((shape, dtype), ...))
    granularity: int
    n_streams: int
    combine: str
    hw: HwModel


def items_signature(items) -> tuple:
    """Structural signature of a region's work items: treedef + per-leaf
    (shape, dtype). Two item pytrees with equal signatures trace to the
    same program under the region's fn."""
    leaves, treedef = jax.tree.flatten(items)
    return (
        str(treedef),
        tuple((tuple(l.shape), str(getattr(l, "dtype", type(l).__name__))) for l in leaves),
    )


@dataclass
class RegionPlan:
    """An accepted schedule plus its compiled executor.

    ``execute(items)`` runs the restructured region; the underlying
    callable is built once per PlanKey, so repeated execution with
    same-signature items reuses the jit cache (no retrace).
    """

    key: PlanKey
    schedule: RelicSchedule
    fn: Callable  # per-item function captured at plan build
    cache_state: str = "miss"  # "miss" on build, "hit" when served from cache
    _compiled: Optional[Callable] = field(default=None, repr=False)
    _compiled_masked: Optional[Callable] = field(default=None, repr=False)

    def __post_init__(self):
        if self._compiled is None:
            g, ns, comb = self.key.granularity, self.key.n_streams, self.key.combine
            fn = self.fn
            self._compiled = jax.jit(
                lambda items: relic_pfor(
                    fn, items, granularity=g, n_streams=ns, combine=comb
                )
            )

    # ------------------------------------------------------------------
    def execute(self, items):
        """Run the restructured region on `items` (must match the plan's
        item signature; anything else retraces or errors)."""
        return self._compiled(items)

    def execute_masked(self, items, valid):
        """Masked fixed-shape execution over a *padded active set*: `items`
        span every slot of a fixed pool, `valid` marks the live ones. The
        mask is data, not shape, so one jit trace serves any live count —
        continuous-batching serving never retraces as requests come and
        go. Invalid rows are zeroed ("stack") or excluded from the
        reduction ("sum"); the masked executor is compiled lazily on
        first use and cached alongside the unmasked one."""
        if self._compiled_masked is None:
            g, ns, comb = self.key.granularity, self.key.n_streams, self.key.combine
            fn = self.fn
            self._compiled_masked = jax.jit(
                lambda items, valid: relic_pfor(
                    fn, items, granularity=g, n_streams=ns, combine=comb, valid=valid
                )
            )
        return self._compiled_masked(items, valid)

    def thunk(self, items) -> Callable:
        """A zero-arg executor bound to `items` (the classic
        ``RegionDecision.parallel_fn`` shape)."""
        return lambda: self.execute(items)

    def describe(self) -> str:
        return f"{self.key.region}: {self.schedule.describe()} combine={self.key.combine}"


# ---------------------------------------------------------------------------
# the cache

_PLAN_CACHE: dict[PlanKey, RegionPlan] = {}
_STATS = {"hits": 0, "misses": 0}


def plan_cache_stats() -> dict:
    return dict(_STATS, size=len(_PLAN_CACHE))


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _STATS.update(hits=0, misses=0)


def _get_or_build(key: PlanKey, schedule: RelicSchedule, fn: Callable) -> RegionPlan:
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _STATS["hits"] += 1
        plan.cache_state = "hit"
        return plan
    _STATS["misses"] += 1
    plan = _PLAN_CACHE[key] = RegionPlan(key=key, schedule=schedule, fn=fn)
    return plan


def plan_for_region(region, schedule: RelicSchedule, hw: HwModel) -> RegionPlan:
    """The plan for (region signature, schedule, hw) — cached. The item
    *content* fingerprint is part of the signature so two same-named,
    same-shaped regions over different data (e.g. two serving engines
    with different params, whose prefilled caches are the items) do not
    alias to one plan."""
    key = PlanKey(
        region=region.name,
        items_sig=items_signature(region.items) + data_fingerprint(region.items),
        granularity=schedule.granularity,
        n_streams=schedule.n_streams,
        combine=getattr(region, "combine", "stack"),
        hw=hw,
    )
    return _get_or_build(key, schedule, region.fn)


def data_fingerprint(tree) -> tuple:
    """Cheap content fingerprint of the arrays a region closes over, so
    same-signature-but-different-data calls do not share a plan. Samples
    head/tail elements only — collisions are possible but require
    identical shapes, dtypes, and boundary values."""
    import numpy as np

    fp = []
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "dtype") and hasattr(leaf, "ravel"):
            flat = leaf.ravel()
            head = np.asarray(flat[:8]).tobytes()
            tail = np.asarray(flat[-8:]).tobytes()
            fp.append((str(leaf.dtype), int(flat.shape[0]), hash(head + tail)))
        else:
            fp.append(repr(leaf)[:64])
    return tuple(fp)


def plan_for(
    name: str,
    fn: Callable,
    items,
    *,
    granularity: int,
    n_streams: int = 2,
    combine: str = "stack",
    hw: HwModel | None = None,
    schedule: RelicSchedule | None = None,
    salt: tuple = (),
) -> RegionPlan:
    """Manual plan construction (no advisory run) — the path benchmarks
    use for fixed-granularity restructured execution. ``salt`` extends
    the cache key (e.g. a ``data_fingerprint`` of closed-over state)."""
    hw = hw or HwModel()
    schedule = schedule or RelicSchedule(
        granularity=granularity, n_streams=n_streams, strategy="smt2"
    )
    key = PlanKey(
        region=name,
        items_sig=items_signature(items) + tuple(salt),
        granularity=granularity,
        n_streams=n_streams,
        combine=combine,
        hw=hw,
    )
    return _get_or_build(key, schedule, fn)


# ---------------------------------------------------------------------------
# suite-level advisory


@dataclass
class SuiteEntry:
    """One benchmark's advisory outcome: the decision, the plan (None if
    rejected), and the built data the region was advised over."""

    benchmark: str
    decision: Any  # RegionDecision
    plan: Optional[RegionPlan]
    data: Any

    @property
    def accepted(self) -> bool:
        return self.decision.accepted


def advise_suite(
    hw: HwModel | None = None,
    *,
    benchmarks: dict | None = None,
    gate_threshold: float = 0.02,
) -> dict[str, SuiteEntry]:
    """Batch-advise every registered benchmark through the tool pipeline.

    Returns name → SuiteEntry. Repeating the call re-uses cached plans
    (same region signatures), so the second suite pass performs no jit
    retracing of restructured regions.
    """
    from repro.bench_suite import BENCHMARKS
    from repro.core.adviser import Aira

    aira = Aira(hw=hw, gate_threshold=gate_threshold)
    out: dict[str, SuiteEntry] = {}
    for name, b in (benchmarks if benchmarks is not None else BENCHMARKS).items():
        data = b.build()
        report = aira.advise(b.workload(data))
        d = report.decisions[0]
        out[name] = SuiteEntry(benchmark=name, decision=d, plan=d.plan, data=data)
    return out
