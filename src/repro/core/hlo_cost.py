"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned layer stack under-reports FLOPs/bytes/collectives by ~L× — which
would poison the roofline table (and per-layer collectives with it).
This walker parses the post-optimization HLO text and:

  * recovers each while loop's trip count from its condition computation
    (the scalar s32 bound constant),
  * propagates multipliers through the call graph
    (while / fusion / call / conditional),
  * counts exact dot FLOPs (2 · numel(result) · Π contracted dims),
  * counts bytes with slice-aware fusion accounting: a fusion whose
    parameter is only dynamic-sliced reads the *slice*, not the operand
    (critical for scan-over-layers: the body reads 1/L of the stacked
    params per iteration),
  * sums per-op collective ring-traffic bytes ×trip-count.

Validated against unrolled-scan ground truth in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]"
)

_SKIP_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "iota", "after-all", "partition-id", "replica-id", "custom-call",
    "copy-start", "copy-done", "opt-barrier",
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_RING_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(text: str) -> float:
    return float(sum(_DTYPE_BYTES[d] * _numel(n) for d, n in _SHAPE_RE.findall(text)))


@dataclass
class _Instr:
    name: str
    op: str
    result_bytes: float
    result_dims: list  # dims of the (first) result shape
    operands: list  # operand instruction names (%refs inside the arg parens)
    line: str


@dataclass
class _Comp:
    name: str
    instrs: dict = field(default_factory=dict)  # name -> _Instr
    order: list = field(default_factory=list)
    s32_consts: dict = field(default_factory=dict)
    param_bytes: dict = field(default_factory=dict)  # param index -> bytes
    param_names: dict = field(default_factory=dict)  # param index -> name


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_TYPE_PREFIX = re.compile(
    r"^\s*(\((?:[^()]*)\)|(?:f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[[\d,]*\](?:\{[\d,*S()]*\})?)\s*"
)
_CONST_S32 = re.compile(r"^s32\[\]\s+constant\((\d+)\)")


def _args_span(rhs: str, op_end: int) -> str:
    """Balanced-paren argument list starting at rhs[op_end] == '('."""
    depth = 0
    for i in range(op_end, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                return rhs[op_end + 1 : i]
    return rhs[op_end + 1 :]


def parse_module(text: str):
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        h = _COMP_HEAD.match(s)
        if h and s.endswith("{"):
            cur = _Comp(h.group(2))
            comps[cur.name] = cur
            if h.group(1):
                entry = cur.name
            continue
        if s == "}" or cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        cm = _CONST_S32.match(rhs)
        if cm:
            cur.s32_consts[name] = int(cm.group(1))
        tp = _TYPE_PREFIX.match(rhs)
        if not tp:
            continue
        result_bytes = _shape_bytes(tp.group(1))
        first_shape = _SHAPE_RE.search(tp.group(1))
        result_dims = (
            [int(x) for x in first_shape.group(2).split(",") if x]
            if first_shape
            else []
        )
        rest = rhs[tp.end():]
        om = re.match(r"([\w\-]+)\(", rest)
        if not om:
            continue
        op = om.group(1)
        args = _args_span(rest, om.end() - 1)
        operands = re.findall(r"%([\w.\-]+)", args)
        ins = _Instr(name, op, result_bytes, result_dims, operands, rest)
        cur.instrs[name] = ins
        cur.order.append(name)
        if op == "parameter":
            pm = re.match(r"parameter\((\d+)\)", rest)
            if pm:
                cur.param_bytes[int(pm.group(1))] = result_bytes
                cur.param_names[int(pm.group(1))] = name
    return comps, entry


def _trip_count(comps, cond_name: str) -> int:
    """Recover the loop bound from the condition computation: find the
    compare (possibly wrapped in a fusion) and resolve its constant
    operand through the call site."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    # direct compare in the condition
    for ins in cond.instrs.values():
        if ins.op == "compare":
            for o in ins.operands:
                if o in cond.s32_consts:
                    return max(1, cond.s32_consts[o])
    # compare wrapped in a fusion: map the compare's parameter index back
    # to the fusion call-site operand
    for ins in cond.instrs.values():
        if ins.op != "fusion":
            continue
        mm = re.search(r"calls=%?([\w.\-]+)", ins.line)
        body = comps.get(mm.group(1)) if mm else None
        if body is None:
            continue
        for b_ins in body.instrs.values():
            if b_ins.op != "compare":
                continue
            for o in b_ins.operands:
                b = body.instrs.get(o)
                if b is not None and b.op == "parameter":
                    pm = re.match(r"parameter\((\d+)\)", b.line)
                    if pm:
                        idx = int(pm.group(1))
                        if idx < len(ins.operands):
                            site = ins.operands[idx]
                            if site in cond.s32_consts:
                                return max(1, cond.s32_consts[site])
    if cond.s32_consts:  # last resort
        return max(1, max(cond.s32_consts.values()))
    return 1


def _source_bytes(comp: _Comp, name: str, depth: int = 0) -> float:
    """Bytes of the HBM-resident source of an operand: follow convert /
    bitcast / copy staging chains back to the producer (a bf16/int8
    tensor upcast to f32 for a CPU dot costs its STORED size on TPU)."""
    i = comp.instrs.get(name)
    if i is None:
        return 0.0
    if depth < 6 and i.op in ("convert", "bitcast", "copy", "reduce-precision") and i.operands:
        src = comp.instrs.get(i.operands[0])
        if src is not None:
            return _source_bytes(comp, i.operands[0], depth + 1)
    return i.result_bytes


def _dot_flops(ins: _Instr, comp: _Comp) -> tuple[float, float]:
    """(flops, operand_bytes) for a dot: 2 · numel(res) · Π contracted."""
    op_bytes = sum(
        _source_bytes(comp, o) for o in ins.operands if o in comp.instrs
    )
    res_n = 1
    for d in ins.result_dims:
        res_n *= d
    k = 1.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
    if m and lhs is not None and lhs.result_dims:
        for c in m.group(1).split(","):
            if c:
                k *= lhs.result_dims[int(c)]
    return 2.0 * res_n * k, op_bytes


_MOVEMENT_OPS = {
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "reduce", "reduce-window", "sort", "select-and-scatter",
}


def _fusion_moves_data(comps, body_name: str) -> bool:
    """True if the fusion body does real data movement. TPU-target byte
    model: elementwise chains, layout copies/transposes/concats and f32
    staging copies are VMEM/register residents on the TPU target — the
    CPU backend materializes them, so they are excluded; slicing,
    scatter/gather and reductions move HBM bytes — but only when the
    moved region is non-trivial (≥4 KiB), so a scalar index slice does
    not reclassify a big elementwise fusion."""
    body = comps.get(body_name)
    if body is None:
        return False
    for i in body.instrs.values():
        if i.op not in _MOVEMENT_OPS:
            continue
        if i.op in ("reduce", "reduce-window"):
            size = max(
                (body.instrs[o].result_bytes for o in i.operands if o in body.instrs),
                default=i.result_bytes,
            )
        elif i.op == "dynamic-update-slice":
            upd = body.instrs.get(i.operands[1]) if len(i.operands) > 1 else None
            size = upd.result_bytes if upd is not None else i.result_bytes
        else:
            size = i.result_bytes
        if size >= 4096:
            return True
    return False


def _fusion_effective_bytes(comps, body_name: str, result_bytes: float) -> float:
    """Traffic a fusion actually moves per call.

    reads — parameters used only by dynamic-slice count as the slice
            size; parameters used only as the *target* of a
            dynamic-update-slice count as the update size (in-place
            update of an aliased buffer); others count full.
    writes — if the body routes its output through dynamic-update-slice,
            only the update region is written; else the full result.
    """
    body = comps.get(body_name)
    if body is None:
        return result_bytes
    reads = 0.0
    dus_update_bytes = 0.0
    has_dus = False

    def _update_size(u):
        upd = body.instrs.get(u.operands[1]) if len(u.operands) > 1 else None
        return upd.result_bytes if upd is not None else u.result_bytes

    def _effective_read(name, size, depth=0):
        """Follow single-use elementwise chains (convert/bitcast/copy —
        the CPU backend materializes f32 copies of bf16 operands that a
        TPU keeps in registers) to the first data-moving consumer."""
        uses = [i for i in body.instrs.values() if name in i.operands]
        if uses and all(
            u.op == "dynamic-slice" and u.operands and u.operands[0] == name
            for u in uses
        ):
            return sum(u.result_bytes for u in uses)
        if uses and all(
            u.op == "dynamic-update-slice" and u.operands and u.operands[0] == name
            for u in uses
        ):
            return sum(_update_size(u) for u in uses)
        if (
            depth < 8
            and len(uses) == 1
            and uses[0].op
            in ("convert", "bitcast", "copy", "reduce-precision", "select")
        ):
            return _effective_read(uses[0].name, size, depth + 1)
        return size

    for idx, pname in body.param_names.items():
        reads += _effective_read(pname, body.param_bytes[idx])
    for i in body.instrs.values():
        if i.op == "dynamic-update-slice":
            has_dus = True
            dus_update_bytes += _update_size(i)
    writes = dus_update_bytes if has_dus else result_bytes
    return reads + writes


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: dict = field(default_factory=lambda: defaultdict(float))
    collective_counts: dict = field(default_factory=lambda: defaultdict(float))


def analyze(text: str) -> HloCost:
    comps, entry = parse_module(text)
    cost = HloCost()

    def dot_walk(comp_name: str, mult: float, stack=()):
        """Inside fusion bodies: only dots contribute (operands counted
        at the call boundary)."""
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        for name in comp.order:
            ins = comp.instrs[name]
            if ins.op == "dot":
                f, _ = _dot_flops(ins, comp)
                cost.flops += f * mult

    def walk(comp_name: str, mult: float, stack=()):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        for name in comp.order:
            ins = comp.instrs[name]
            op = ins.op
            if op == "while":
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                trip = _trip_count(comps, mc.group(1)) if mc else 1
                if mb:
                    walk(mb.group(1), mult * trip, stack + (comp_name,))
                continue
            if op == "conditional":
                for mm in re.finditer(r"%([\w.\-]+)", ins.line.split("branch_computations")[-1]):
                    walk(mm.group(1), mult, stack + (comp_name,))
                cost.bytes += 2 * ins.result_bytes * mult
                continue
            coll = next(
                (c for c in COLLECTIVES if re.match(rf"{c}(-start)?\(", ins.line)), None
            )
            if coll:
                payload = ins.result_bytes * _RING_FACTOR[coll]
                cost.collective_bytes += payload * mult
                cost.collective_by_op[coll] += payload * mult
                cost.collective_counts[coll] += mult
                cost.bytes += 2 * ins.result_bytes * mult
                continue
            if op in _SKIP_OPS:
                continue
            if op == "dot":
                f, ob = _dot_flops(ins, comp)
                cost.flops += f * mult
                cost.bytes += (ob + ins.result_bytes) * mult
                continue
            if op in ("fusion", "call", "map"):
                mm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.line)
                body = mm.group(1) if mm else None
                if body and _fusion_moves_data(comps, body):
                    cost.bytes += (
                        _fusion_effective_bytes(comps, body, ins.result_bytes) * mult
                    )
                if body:
                    dot_walk(body, mult, stack + (comp_name,))
                continue
            if op in ("reduce", "reduce-window"):
                ob = sum(
                    comp.instrs[o].result_bytes
                    for o in ins.operands
                    if o in comp.instrs
                )
                cost.bytes += (ob + ins.result_bytes) * mult
                continue
            if op == "dynamic-update-slice":
                upd = (
                    comp.instrs[ins.operands[1]].result_bytes
                    if len(ins.operands) > 1 and ins.operands[1] in comp.instrs
                    else ins.result_bytes
                )
                cost.bytes += 2 * upd * mult
                continue
            if op in ("dynamic-slice", "gather", "slice", "sort", "scatter",
                      "select-and-scatter"):
                cost.bytes += 2 * ins.result_bytes * mult
                continue
            # generic elementwise / broadcast / convert / reshape, and the
            # CPU backend's layout copies (copy/transpose/concatenate/pad):
            # VMEM/register residents on the TPU target — their traffic is
            # captured at the dot/reduce/slice/collective boundaries above.
            continue

    if entry:
        walk(entry, 1.0)
    return cost


def analyze_compiled(compiled) -> HloCost:
    return analyze(compiled.as_text())
