"""The Aira tool surface: discrete adviser tools + the pipeline executor.

The paper's agent drives five MCP tools — profiler, static dependence
(BOLT), dynamic dependence (DynamoRIO), SMT-aware simulator (Sniper),
and the Relic restructurer — and an LLM decides, stage by stage, whether
to continue. This module is that architecture made explicit (DESIGN.md
§1):

* ``AdviserTool``   — uniform tool interface: ``run(region, ctx) ->
                      StageResult``. Tools never reject; they report.
* ``ToolPipeline``  — the executor. Owns the stage log, early-reject,
                      and the ``force=`` override semantics that used to
                      be inlined in ``adviser.Aira._advise_region``.
* ``AdviserPolicy`` — the decision seat. ``SpecPolicy`` implements the
                      deterministic spec rules (core/spec.py);
                      ``RecordingPolicy``/``ReplayPolicy`` capture and
                      replay decision streams for tests, and are the
                      seam where an actual LLM policy would plug in.

The pipeline produces ``RegionDecision``s; accepted regions carry a
cached ``RegionPlan`` (core/plan.py) so repeated advise/execute of the
same region signature does not retrace.
"""
from __future__ import annotations

import sys
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax

from repro.core import deps as deps_mod
from repro.core.overlap_model import HwModel, Microtask, OverlapModel, gate
from repro.core.relic import RelicSchedule, choose_schedule

def _flight():
    """``(telemetry, adviser_tid)`` when the global serving flight
    recorder (serve/telemetry.py, DESIGN.md §8) is armed, else
    ``(None, 0)``.  A ``sys.modules`` lookup, never an import: enabling
    telemetry requires importing the module, so an absent module means
    the recorder is off — and ``core/`` stays free of any ``serve``
    dependency."""
    mod = sys.modules.get("repro.serve.telemetry")
    if mod is None:
        return None, 0
    tel = mod.get_telemetry()
    if not tel.enabled:
        return None, 0
    return tel, mod.TID_ADVISER


# stage verdicts a tool can report
PASS = "pass"
REJECT = "reject"
SKIP = "skip"

# actions a policy can take on a verdict
CONTINUE = "continue"
STOP = "stop"


@dataclass
class StageResult:
    """One tool invocation's report: a verdict plus a log line.

    ``payload`` carries tool-specific artifacts (the static report, the
    chosen schedule, …) for later stages via ``ToolContext.artifacts``.
    """

    stage: str
    verdict: str  # PASS | REJECT | SKIP
    log: Optional[str] = None  # None → no stage-log line
    payload: Any = None


@dataclass
class ToolContext:
    """Cross-stage state for one region's advisory run."""

    hw: HwModel
    model: OverlapModel
    gate_threshold: float = 0.02
    n_items: int = 0
    artifacts: dict = field(default_factory=dict)


@runtime_checkable
class AdviserTool(Protocol):
    """One MCP-analogue tool. ``name`` doubles as the stage-log prefix."""

    name: str

    def run(self, region, ctx: ToolContext) -> StageResult: ...


# ---------------------------------------------------------------------------
# the five tools


class ProfileTool:
    """perf+LBR analogue: package the region's napkin/profile-derived
    per-item cost as a Microtask for the simulator."""

    name = "profile"

    def run(self, region, ctx: ToolContext) -> StageResult:
        task = Microtask(
            flops=region.task_flops,
            bytes=region.task_bytes,
            chain=region.task_chain,
            vector=region.vector,
        )
        ctx.artifacts["microtask"] = task
        unit = "VPU" if region.vector else "MXU"
        log = (
            f"{ctx.n_items} items × ({region.task_flops:.0f} flop, "
            f"{region.task_bytes:.0f} B, chain={region.task_chain}) [{unit}]"
        )
        return StageResult(self.name, PASS, log, payload=task)


class StaticDepsTool:
    """BOLT analogue: jaxpr def-use walk over one sample item."""

    name = "static"

    def run(self, region, ctx: ToolContext) -> StageResult:
        sample = jax.tree.map(lambda a: a[0], region.items)
        srep = deps_mod.static_deps(region.fn, sample)
        ctx.artifacts["static"] = srep
        return StageResult(self.name, PASS, srep.summary(), payload=srep)


class DynamicDepsTool:
    """DynamoRIO analogue: replay the recorded access trace under the
    proposed partition; without a trace, a non-trivially-parallel region
    (shared writes in the static report) cannot be cleared."""

    name = "dynamic"

    def run(self, region, ctx: ToolContext) -> StageResult:
        if region.trace is not None:
            conflict, why = deps_mod.check_conflicts(region.trace, n_tasks=2)
            return StageResult(self.name, REJECT if conflict else PASS, why)
        srep = ctx.artifacts.get("static")
        if srep is not None and not srep.trivially_parallel:
            return StageResult(
                self.name, REJECT, "no trace supplied for non-trivial region → reject"
            )
        return StageResult(self.name, SKIP)  # trivially parallel: no trace needed


class OverlapSimTool:
    """Sniper analogue: price serial vs smt2 vs smp2 over the granularity
    sweep and apply the profitability gate."""

    name = "simulate"

    def run(self, region, ctx: ToolContext) -> StageResult:
        task = ctx.artifacts["microtask"]
        schedule = choose_schedule(
            ctx.model,
            task.flops,
            task.bytes,
            ctx.n_items,
            chain=task.chain,
            vector=task.vector,
        )
        pred = schedule.prediction
        ok, why = gate(pred, ctx.gate_threshold)
        ctx.artifacts["schedule"] = schedule
        ctx.artifacts["prediction"] = pred
        log = (
            f"{why} (serial {pred.serial*1e6:.1f}µs, "
            f"smt2 {pred.smt2*1e6:.1f}µs, smp2 {pred.smp2*1e6:.1f}µs)"
        )
        verdict = PASS if (ok and schedule.strategy != "serial") else REJECT
        return StageResult(self.name, verdict, log, payload=schedule)


class RelicRestructureTool:
    """Relic analogue: rewrite the accepted region onto the Relic API at
    the simulator's granularity, through the cached plan layer."""

    name = "restructure"

    def run(self, region, ctx: ToolContext) -> StageResult:
        from repro.core.plan import plan_for_region  # avoid import cycle

        schedule = ctx.artifacts.get("schedule")
        pred = ctx.artifacts.get("prediction")
        if region.force and schedule is not None and schedule.strategy == "serial":
            # gate bypassed on a serial-best region: impose the paper's
            # forced smt2 schedule (1-Hop/BVH scenario)
            schedule = RelicSchedule(
                granularity=max(1, ctx.n_items // 2),
                n_streams=2,
                strategy="smt2",
                prediction=pred,
            )
            ctx.artifacts["schedule"] = schedule

        if region.restructure is not None:
            ctx.artifacts["parallel_fn"] = region.restructure
            return StageResult(self.name, PASS, "custom Relic implementation")

        plan = plan_for_region(region, schedule, ctx.hw)
        ctx.artifacts["plan"] = plan
        ctx.artifacts["parallel_fn"] = plan.thunk(region.items)
        return StageResult(
            self.name,
            PASS,
            f"relic_pfor(gran={schedule.granularity}) [plan {plan.cache_state}]",
        )


# ---------------------------------------------------------------------------
# serving-layer speculation advice (the OverlapSimTool analogue one
# level up: price a helper stream before committing to it)


@dataclass
class SpecMeasurement:
    """Measured speculative-serving profile — what the advisory gate
    prices, as ``ProfileTool`` packages a region's cost as a Microtask.

    ``draft_ms_per_token`` is the draft stream's marginal cost;
    ``verify_ms`` maps speculation depth K to one verify-step
    wall-clock (K=0 being the plain decode step); ``acceptance_rate``
    is the measured per-draft-token greedy acceptance probability."""

    draft_ms_per_token: float
    verify_ms: dict
    acceptance_rate: float

    def verify_cost(self, k: int) -> float:
        """Verify-step cost at depth ``k``, linearly interpolated (and
        clamped) between the measured depths."""
        ks = sorted(self.verify_ms)
        if k in self.verify_ms:
            return float(self.verify_ms[k])
        lo = max((x for x in ks if x < k), default=ks[0])
        hi = min((x for x in ks if x > k), default=ks[-1])
        if hi == lo:
            return float(self.verify_ms[lo])
        w = (k - lo) / (hi - lo)
        return float((1 - w) * self.verify_ms[lo] + w * self.verify_ms[hi])


def expected_tokens_per_round(p: float, k: int) -> float:
    """E[tokens committed per verify round] at depth ``k`` under i.i.d.
    per-token acceptance probability ``p``: 1 + p + p² + … + p^k (the
    round always commits at least the corrected token)."""
    return float(sum(p**i for i in range(k + 1)))


def price_speculation(m: SpecMeasurement, ks, threshold: float = 0.02):
    """The speculation pricing analytic, as a pure function: expected
    per-output-token latency at each candidate depth in ``ks`` from a
    measured (or live-estimated) draft cost, verify cost, and
    acceptance rate, gated on ``threshold`` predicted gain.

    Shared verbatim by the offline gate (``SpeculationAdvisorTool``,
    whose golden decisions pin these numbers) and the online controller
    (``serve.controller.OnlineAdviser``, which substitutes windowed
    live estimates for the offline probe). Returns ``(best_k,
    best_cost_ms_per_token, gain_vs_k0, costs)`` where ``costs`` maps
    every priced depth (including 0) to its expected ms/output-token.
    """
    base = m.verify_cost(0)
    costs = {0: base}
    best_k, best_cost = 0, base
    for k in ks:
        if k <= 0:
            continue
        cost = (k * m.draft_ms_per_token + m.verify_cost(k)) / (
            expected_tokens_per_round(m.acceptance_rate, k)
        )
        costs[int(k)] = cost
        if cost < best_cost:
            best_k, best_cost = k, cost
    gain = (base / best_cost - 1.0) if best_cost > 0 else 0.0
    if gain <= threshold:
        best_k, best_cost, gain = 0, base, 0.0
    return best_k, best_cost, gain, costs


def price_backends(step_ms: dict, threshold: float = 0.02, baseline: str = "reference"):
    """The backend pricing analytic, as a pure function: pick the
    cheapest measured backend, committing away from ``baseline`` only
    when the predicted gain clears ``threshold`` — the same
    commit-only-on-predicted-win rule as ``price_speculation``.

    Shared verbatim by the offline gate (``KernelAdvisorTool``, whose
    baseline is always ``"reference"``) and the online controller
    (whose baseline is the *currently serving* backend, so hysteresis
    is priced against the status quo). Returns ``(best_backend,
    best_ms, gain_vs_baseline)``."""
    base = float(step_ms[baseline])
    best, best_ms = baseline, base
    for backend, ms in sorted(step_ms.items()):
        if backend != baseline and float(ms) < best_ms:
            best, best_ms = backend, float(ms)
    gain = (base / best_ms - 1.0) if best_ms > 0 else 0.0
    if gain <= threshold:
        best, best_ms, gain = baseline, base, 0.0
    return best, best_ms, gain


class SpeculationAdvisorTool:
    """Sniper-gate analogue for speculative serving: price expected
    per-output-token latency at each candidate depth from a measured
    draft cost + acceptance rate, and pick K ∈ ``ks`` — K=0 (don't
    speculate) unless the predicted gain clears the threshold, the same
    commit-only-on-predicted-win rule as ``OverlapSimTool``.

    As a pipeline stage it reports only for regions carrying a
    ``spec_measurement`` (compute regions silently SKIP, so the
    advisory stage log — and the golden decisions — are unchanged for
    non-serving workloads); ``serve/speculative.advise_depth`` is the
    measuring front end and ``engine.serve(spec=...)`` honors the
    decision."""

    name = "speculate"

    def __init__(self, ks=(0, 2, 4, 8)):
        self.ks = tuple(ks)

    def choose(self, m: SpecMeasurement, threshold: float = 0.02):
        """(chosen K, predicted gain, log line) for measurement ``m``."""
        best_k, best_cost, gain, _costs = price_speculation(m, self.ks, threshold)
        base = m.verify_cost(0)
        log = (
            f"accept={m.acceptance_rate:.2f} "
            f"draft={m.draft_ms_per_token:.3f}ms/tok "
            f"base={base:.2f}ms/tok → K={best_k} "
            f"({best_cost:.2f}ms/tok, {gain:+.1%})"
        )
        tel, tid = _flight()
        if tel is not None:
            # audit trail: the decision WITH its priced inputs, so an
            # exported trace shows why this K was chosen
            tel.count("adviser.decisions")
            tel.tracer.instant(
                "speculation-decision", "adviser", tid=tid,
                args={
                    "k": best_k,
                    "gain": round(gain, 4),
                    "acceptance_rate": round(m.acceptance_rate, 4),
                    "draft_ms_per_token": round(m.draft_ms_per_token, 4),
                    "base_ms_per_token": round(base, 4),
                    "chosen_ms_per_token": round(best_cost, 4),
                    "candidates": list(self.ks),
                },
            )
        return best_k, gain, log

    def run(self, region, ctx: ToolContext) -> StageResult:
        m = ctx.artifacts.get(
            "spec_measurement", getattr(region, "spec_measurement", None)
        )
        if m is None:
            return StageResult(self.name, SKIP)
        k, gain, log = self.choose(m, ctx.gate_threshold)
        ctx.artifacts["spec_k"] = k
        return StageResult(self.name, PASS, log, payload=k)


@dataclass(frozen=True)
class KernelMeasurement:
    """Measured attention-step cost for one serving cell — what the
    kernel-backend gate prices, as ``SpecMeasurement`` is to the
    speculation gate.

    ``family``/``layout``/``k`` name the cell (model family, KV layout
    ``"slot" | "paged"``, speculation depth with 0 = plain decode);
    ``step_ms`` maps backend name → measured per-step wall-clock for
    that cell. A ``"reference"`` entry is required — it is the baseline
    the predicted gain is quoted against."""

    family: str
    layout: str
    k: int
    step_ms: tuple  # ((backend, ms), ...) — hashable, dict-constructed

    @staticmethod
    def make(family: str, layout: str, k: int, step_ms: dict) -> "KernelMeasurement":
        if "reference" not in step_ms:
            raise ValueError("KernelMeasurement needs a 'reference' baseline timing")
        return KernelMeasurement(family, layout, int(k), tuple(sorted(step_ms.items())))

    @property
    def timings(self) -> dict:
        return dict(self.step_ms)


class KernelAdvisorTool:
    """Backend gate for the decode/verify attention step: pick the
    attention backend per (family, layout, K) cell from *measured*
    per-step cost, the same commit-only-on-predicted-win rule as
    ``OverlapSimTool`` — ``"reference"`` (don't switch) unless a kernel
    backend's measured gain clears the threshold. Measured, not
    assumed: on a host where the interpreted kernel is slower than the
    jnp reference the gate says reference, and on TPU the compiled
    kernel has to *show* its dense-gather savings to be chosen.

    As a pipeline stage it reports only for regions carrying a
    ``kernel_measurement`` (compute regions silently SKIP, so the
    advisory stage log — and the golden decisions — are unchanged);
    ``benchmarks/serving_load.run_backend_sweep`` is the measuring
    front end and ``engine.serve(attention_backend=...)`` honors the
    decision (DESIGN.md §4)."""

    name = "kernel"

    def choose(self, m: KernelMeasurement, threshold: float = 0.02):
        """(chosen backend, predicted gain, log line) for cell ``m``."""
        t = m.timings
        best, best_ms, gain = price_backends(t, threshold, baseline="reference")
        timings = " ".join(f"{b}={float(ms):.2f}ms" for b, ms in sorted(t.items()))
        log = (
            f"{m.family}/{m.layout}/K={m.k}: {timings} → {best} "
            f"({best_ms:.2f}ms/step, {gain:+.1%})"
        )
        tel, tid = _flight()
        if tel is not None:
            tel.count("adviser.decisions")
            tel.tracer.instant(
                "kernel-backend-decision", "adviser", tid=tid,
                args={
                    "backend": best,
                    "gain": round(gain, 4),
                    "cell": f"{m.family}/{m.layout}/K={m.k}",
                    "step_ms": {b: round(float(ms), 4) for b, ms in sorted(t.items())},
                },
            )
        return best, gain, log

    def run(self, region, ctx: ToolContext) -> StageResult:
        m = ctx.artifacts.get(
            "kernel_measurement", getattr(region, "kernel_measurement", None)
        )
        if m is None:
            return StageResult(self.name, SKIP)
        backend, gain, log = self.choose(m, ctx.gate_threshold)
        ctx.artifacts["attention_backend"] = backend
        return StageResult(self.name, PASS, log, payload=backend)


DEFAULT_TOOLS: tuple = (
    ProfileTool(),
    StaticDepsTool(),
    DynamicDepsTool(),
    OverlapSimTool(),
    RelicRestructureTool(),
    SpeculationAdvisorTool(),
    KernelAdvisorTool(),
)


# ---------------------------------------------------------------------------
# policies


@runtime_checkable
class AdviserPolicy(Protocol):
    """The decision seat between stages: maps a StageResult to CONTINUE
    or STOP. The paper puts an LLM here; SpecPolicy puts the spec's
    deterministic rules here."""

    def decide(self, result: StageResult, region, ctx: ToolContext) -> str: ...


class SpecPolicy:
    """Deterministic spec rules: stop on any tool reject."""

    def decide(self, result: StageResult, region, ctx: ToolContext) -> str:
        return STOP if result.verdict == REJECT else CONTINUE


@dataclass
class RecordingPolicy:
    """Wraps a policy and records every (region, stage, verdict, action)
    so a decision stream can be replayed (or asserted on) in tests."""

    inner: AdviserPolicy
    record: list = field(default_factory=list)

    def decide(self, result: StageResult, region, ctx: ToolContext) -> str:
        action = self.inner.decide(result, region, ctx)
        self.record.append((region.name, result.stage, result.verdict, action))
        return action


@dataclass
class ReplayPolicy:
    """Replays a RecordingPolicy's decision stream verbatim, ignoring
    tool verdicts — deterministic adviser behaviour in tests without
    re-running the underlying analyses."""

    record: list
    _pos: int = 0

    def decide(self, result: StageResult, region, ctx: ToolContext) -> str:
        if self._pos >= len(self.record):
            raise IndexError("ReplayPolicy: decision stream exhausted")
        name, stage, _verdict, action = self.record[self._pos]
        if (name, stage) != (region.name, result.stage):
            raise ValueError(
                f"ReplayPolicy: recorded ({name}, {stage}) but pipeline is at "
                f"({region.name}, {result.stage})"
            )
        self._pos += 1
        return action


# ---------------------------------------------------------------------------
# the executor


class ToolPipeline:
    """Runs the tool sequence over one region.

    Owns the three behaviours that used to be inlined in the adviser:
    the stage log (one ``"stage: …"`` line per tool report), early
    reject (a STOP from the policy ends the run), and the ``force=``
    override (a forced region logs the bypass and keeps going — the
    paper's 1-Hop/BVH scenario).
    """

    def __init__(self, tools=DEFAULT_TOOLS, policy: AdviserPolicy | None = None):
        self.tools = tuple(tools)
        self.policy = policy or SpecPolicy()

    def run(self, region, ctx: ToolContext):
        from repro.core.adviser import RegionDecision  # one-way at runtime

        log: list[str] = []
        ctx.n_items = jax.tree.leaves(region.items)[0].shape[0]
        tel, tid = _flight()

        for tool in self.tools:
            t0 = time.perf_counter() if tel is not None else 0.0
            result = tool.run(region, ctx)
            if tel is not None and result.verdict != SKIP:
                tr = tel.tracer
                a = tr.to_us(t0)
                args = {"region": region.name, "verdict": result.verdict}
                if result.log:
                    args["log"] = result.log
                tr.complete(
                    f"tool:{result.stage}", "adviser", a, tr.now_us() - a,
                    tid=tid, args=args,
                )
            if result.log:
                log.append(f"{result.stage}: {result.log}")
            action = self.policy.decide(result, region, ctx)
            if action == STOP:
                if region.force:
                    log.append(
                        f"force=True: {result.stage} reject bypassed "
                        "(paper's 1-Hop/BVH scenario)"
                    )
                    continue
                schedule = ctx.artifacts.get("schedule")
                pred = ctx.artifacts.get("prediction")
                return RegionDecision(
                    region=region.name,
                    stage_log=log,
                    accepted=False,
                    schedule=schedule,
                    predicted_gain=pred.gain("smt2") if pred is not None else 0.0,
                    parallel_fn=None,
                    plan=None,
                )

        schedule = ctx.artifacts["schedule"]
        pred = ctx.artifacts["prediction"]
        return RegionDecision(
            region=region.name,
            stage_log=log,
            accepted=True,
            schedule=schedule,
            predicted_gain=pred.gain(schedule.strategy),
            parallel_fn=ctx.artifacts["parallel_fn"],
            plan=ctx.artifacts.get("plan"),
        )
