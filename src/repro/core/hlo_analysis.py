"""Compiled-HLO analysis: collective bytes, op-class histogram, hotspots.

This is the tooling layer the paper builds on BOLT: instead of x86 binary
analysis we parse the SPMD-partitioned HLO of a compiled XLA executable.
All byte counts are *per device* (SPMD: every device runs the same
program on its shard).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# bytes a ring implementation moves through each chip's ICI links, as a
# multiple of the instruction's per-device payload size
_RING_FACTOR = {
    "all-gather": 1.0,       # receives (n-1)/n of result ≈ result bytes
    "all-reduce": 2.0,       # reduce-scatter + all-gather phases
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shapes_bytes(text: str) -> int:
    return sum(_DTYPE_BYTES[d] * _numel(dims) for d, dims in _SHAPE_RE.findall(text))


def _numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=lambda: defaultdict(int))
    bytes_by_op: dict = field(default_factory=lambda: defaultdict(float))
    bytes_total: float = 0.0
    instructions: list = field(default_factory=list)

    def summary(self) -> str:
        parts = [
            f"{op}×{self.counts[op]}={self.bytes_by_op[op]/2**20:.1f}MiB"
            for op in sorted(self.counts)
        ]
        return f"total={self.bytes_total/2**20:.1f}MiB  " + "  ".join(parts)


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device ICI bytes of every collective in a compiled HLO module.

    For each collective instruction we take the *result* shapes (per-device
    shard sizes in SPMD HLO) times a ring-schedule factor.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", line)
        if not m:
            continue
        rhs = m.group(1)
        for op in COLLECTIVE_OPS:
            # match the op as the instruction name: "... op(" or "... op-start("
            if re.search(rf"\b{op}(-start)?\(", rhs):
                # result shapes appear before the op name
                head = rhs.split(op)[0]
                nbytes = _shapes_bytes(head) * _RING_FACTOR[op]
                stats.counts[op] += 1
                stats.bytes_by_op[op] += nbytes
                stats.bytes_total += nbytes
                stats.instructions.append((op, nbytes, line[:160]))
                break
    return stats


@dataclass
class OpStats:
    """Rough per-op-class byte/flop attribution from HLO (hotspot ranking)."""

    flops_by_op: dict = field(default_factory=lambda: defaultdict(float))
    bytes_by_op: dict = field(default_factory=lambda: defaultdict(float))

    def hotspots(self, peak_flops: float, hbm_bw: float, top: int = 10):
        """Rank op classes by modeled time = max(flop-time, byte-time)."""
        t = {}
        for op in set(self.flops_by_op) | set(self.bytes_by_op):
            t[op] = max(
                self.flops_by_op.get(op, 0.0) / peak_flops,
                self.bytes_by_op.get(op, 0.0) / hbm_bw,
            )
        return sorted(t.items(), key=lambda kv: -kv[1])[:top]


_DOT_RE = re.compile(r"dot\(|convolution\(")


def op_stats(hlo_text: str) -> OpStats:
    """Walk HLO instructions; attribute dot FLOPs and all I/O bytes.

    dot flops: 2 · numel(result) · contracted-dim (parsed from the
    dot_dimension_numbers operand shapes when present; else estimated from
    operand sizes).
    """
    stats = OpStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", line)
        if not m or " = " in line and line.startswith("ROOT tuple"):
            continue
        rhs = m.group(1)
        om = re.match(r"(?:\(?[\w\[\],\s]*\)?\s*)?([a-z][\w\-]*)\(", rhs)
        shapes = _SHAPE_RE.findall(rhs)
        if not shapes:
            continue
        opname = om.group(1) if om else "unknown"
        result_b = _DTYPE_BYTES[shapes[0][0]] * _numel(shapes[0][1])
        all_b = sum(_DTYPE_BYTES[d] * _numel(n) for d, n in shapes)
        stats.bytes_by_op[opname] += all_b
        if opname in ("dot", "convolution") and len(shapes) >= 3:
            res_n = _numel(shapes[0][1])
            lhs_n = _numel(shapes[1][1])
            rhs_n = _numel(shapes[2][1])
            # contracted size ≈ sqrt(lhs·rhs/res) for plain matmul
            k = max(1.0, (lhs_n * rhs_n / max(res_n, 1)) ** 0.5)
            stats.flops_by_op[opname] += 2.0 * res_n * k
    return stats
