"""Relic runtime analogue: fine-grained microtask partitioning + paired
stream co-scheduling, expressed in JAX.

The original Relic [Los & Petushkov 2024] is a task-parallel runtime whose
dispatch is cheap enough (~100 ns) to pay off at microsecond-kernel
granularity on the two hardware threads of one SMT core. The TPU-native
re-expression (DESIGN.md §2):

  relic_pfor     — split an item-parallel region into `n_streams`
                   interleaved chunk streams; chunk size = the task
                   granularity. Lowered as a batched (vmap) dimension over
                   streams × a sequential scan over chunks — i.e. the same
                   compute restructured so a co-scheduling substrate
                   (Pallas grid / XLA async pair) can overlap the streams.
  RelicSchedule  — the chosen (granularity, n_streams, strategy) +
                   the overlap model's prediction; attached to restructured
                   regions so reports can show *why* a kernel was accepted.

The 20 usage examples the paper feeds its LLM live in core/spec.py
(RELIC_EXAMPLES) and double as doctests exercised by the test suite.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.overlap_model import Microtask, OverlapModel, SchedulePrediction


@dataclass
class RelicSchedule:
    granularity: int
    n_streams: int
    strategy: str  # "smt2" | "smp2" | "serial"
    prediction: Optional[SchedulePrediction] = None

    def describe(self) -> str:
        p = self.prediction
        gain = f" predicted {p.gain(self.strategy)*100:+.1f}%" if p and self.strategy != "serial" else ""
        return f"{self.strategy}(gran={self.granularity}, streams={self.n_streams}){gain}"


def relic_pfor(
    fn: Callable,
    xs,
    *,
    granularity: int,
    n_streams: int = 2,
    combine: str = "stack",
    valid=None,
):
    """Item-parallel region → co-scheduled chunk streams.

    fn: per-item function (vmap-able). xs: leading-axis item array(s).
    Items are grouped into chunks of `granularity`; chunks are dealt
    round-robin to `n_streams` streams (the SMT thread pair); each stream
    processes its chunks sequentially (lax.scan = the Relic task queue),
    streams are batched (vmap = co-scheduled).

    combine="stack": results in the original item order (the default).
    combine="sum": the tree-sum of per-item results over the item axis —
    each stream accumulates its chunk partials in the scan carry (the
    Relic reduction-variable idiom), then partials are summed across
    streams; padding items are masked out of the sum.

    valid: optional [n_items] boolean mask for fixed-shape execution over
    a *padded active set* (a serving slot pool where only some slots hold
    live requests). Invalid items still flow through ``fn`` — the traced
    shape stays static, so one jit trace serves any live count — but
    their rows are zeroed in "stack" results and excluded from "sum"
    reductions.
    """
    if combine not in ("stack", "sum"):
        raise ValueError(f"combine must be 'stack' or 'sum', got {combine!r}")
    leaves = jax.tree.leaves(xs)
    n = leaves[0].shape[0]
    if valid is not None:
        valid = jnp.asarray(valid).reshape((n,)).astype(bool)
    g = max(1, min(granularity, n))
    n_chunks = n // g
    n_padded = n
    if n_chunks % n_streams or n % g:
        # pad items to streams×granularity boundary
        target = ((n + g * n_streams - 1) // (g * n_streams)) * g * n_streams
        pad = target - n
        xs = jax.tree.map(
            lambda a: jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)], axis=0),
            xs,
        )
        if valid is not None:
            valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
        n_chunks = target // g
        n_padded = target

    per_stream = n_chunks // n_streams
    # [n_items,...] → [n_streams, per_stream, g, ...] (round-robin deal)
    def deal(a):
        a = a.reshape(n_chunks, g, *a.shape[1:])
        return a.reshape(per_stream, n_streams, g, *a.shape[2:]).swapaxes(0, 1)

    xs_dealt = jax.tree.map(deal, xs)

    if combine == "sum":
        keep = jnp.arange(n_padded) < n
        if valid is not None:
            keep = keep & valid
        valid_dealt = deal(keep)  # [streams, per_stream, g]
        item_struct = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape[3:], a.dtype), xs_dealt
        )
        out_struct = jax.eval_shape(fn, item_struct)

        def stream_sum(stream_chunks, stream_valid):
            def step(acc, chunk_mask):
                chunk, m = chunk_mask
                ys = jax.vmap(fn)(chunk)
                part = jax.tree.map(
                    lambda y: jnp.where(
                        m.reshape((g,) + (1,) * (y.ndim - 1)), y, jnp.zeros_like(y)
                    ).sum(axis=0),
                    ys,
                )
                return jax.tree.map(jnp.add, acc, part), None

            zero = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), out_struct)
            acc, _ = jax.lax.scan(step, zero, (stream_chunks, stream_valid))
            return acc

        partials = jax.vmap(stream_sum)(xs_dealt, valid_dealt)  # co-scheduled streams
        return jax.tree.map(lambda a: a.sum(axis=0), partials)

    def stream_fn(stream_chunks):  # sequential task queue of one stream
        def step(_, chunk):
            return None, jax.vmap(fn)(chunk)

        _, ys = jax.lax.scan(step, None, stream_chunks)
        return ys

    ys = jax.vmap(stream_fn)(xs_dealt)  # co-scheduled streams

    # undo the deal: [streams, per_stream, g, ...] → [n_items, ...]
    def undeal(a):
        a = a.swapaxes(0, 1).reshape(n_chunks * g, *a.shape[3:])
        return a[:n]

    ys = jax.tree.map(undeal, ys)
    if valid is not None:
        live = valid[:n]
        ys = jax.tree.map(
            lambda y: jnp.where(
                live.reshape((n,) + (1,) * (y.ndim - 1)), y, jnp.zeros_like(y)
            ),
            ys,
        )
    return ys


def choose_schedule(
    model: OverlapModel,
    task_flops: float,
    task_bytes: float,
    n_items: int,
    *,
    chain: int = 0,
    vector: bool = False,
    granularities=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024),
    strategies=("smt2",),
) -> RelicSchedule:
    """Pick the best (granularity, strategy) under the overlap model —
    what the paper's LLM does with the Sniper tool output. The default
    strategy set is smt2 only: the paper's premise is that the heavy
    threads of a latency-critical app own the physical cores, so only
    the sibling hardware thread is available (pass smp2 to widen).
    Granularity is capped at n/4 so at least two tasks per stream exist
    to pipeline."""
    best = None
    for g in granularities:
        if g > max(1, n_items // 4):
            break
        t = Microtask(task_flops * g, task_bytes * g, chain=chain * g, vector=vector)
        p = model.predict(t, max(1, n_items // g))
        for strat in strategies:
            tt = getattr(p, strat)
            if best is None or tt < best[0]:
                best = (tt, g, strat, p)
    tt, g, strat, p = best
    if p.serial <= tt:
        return RelicSchedule(granularity=n_items, n_streams=1, strategy="serial", prediction=p)
    return RelicSchedule(granularity=g, n_streams=2, strategy=strat, prediction=p)
