"""Aira: the end-to-end parallelization adviser (paper §V).

``Aira.advise(workload)`` executes the specification stages
(core/spec.py) over a workload's annotated regions:

  profile → annotate → static deps → dynamic deps → simulate (gate)
  → restructure with the Relic analogue

The paper drives these stages with Claude Sonnet 4 inside Cursor via MCP
tools; the tool surface here is identical — five discrete
``AdviserTool``s (core/tools.py) run by a ``ToolPipeline`` whose
decision seat is an ``AdviserPolicy``. The default ``SpecPolicy`` is the
spec's deterministic rules; swap in a recording/replay policy (or an
actual LLM) without touching the tools — see DESIGN.md §2 for why the
base model is not the contribution being reproduced.

Accepted regions carry a cached ``RegionPlan`` (core/plan.py): the
schedule plus a jit-compiled ``parallel_fn``, reusable across
benchmarks, figures, examples, and the serving engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core import deps as deps_mod
from repro.core.overlap_model import HwModel, OverlapModel
from repro.core.relic import RelicSchedule
from repro.core.spec import AIRA_SPEC, PROMPT
from repro.core.tools import (
    DEFAULT_TOOLS,
    AdviserPolicy,
    SpecPolicy,
    ToolContext,
    ToolPipeline,
)


@dataclass
class Region:
    """An annotated candidate region inside a hotspot function."""

    name: str
    fn: Callable  # per-item function
    items: Any  # pytree, leading axis = work items
    task_flops: float  # per-item FLOPs (napkin/profile derived)
    task_bytes: float  # per-item bytes moved
    task_chain: int = 0  # dependent accesses per item (tree/list hops)
    vector: bool = True  # pointer-chasing/gather regions are VPU-bound
    trace: Optional[deps_mod.MemoryTrace] = None
    restructure: Optional[Callable] = None  # custom parallel impl
    force: bool = False  # bypass the gate (paper's 1-Hop/BVH case)
    combine: str = "stack"  # how the plan combines per-item results


@dataclass
class Workload:
    name: str
    serial_fn: Callable  # () -> result (the latency-critical step)
    regions: list[Region] = field(default_factory=list)


@dataclass
class RegionDecision:
    region: str
    stage_log: list[str]
    accepted: bool
    schedule: Optional[RelicSchedule]
    predicted_gain: float
    parallel_fn: Optional[Callable] = None
    plan: Optional[Any] = None  # RegionPlan when accepted via the plan layer

    def summary(self) -> str:
        s = "ACCEPT" if self.accepted else "reject"
        sched = self.schedule.describe() if self.schedule else "-"
        return f"[{s}] {self.region:20s} {sched:40s} | " + " ; ".join(self.stage_log)


@dataclass
class AdviceReport:
    workload: str
    decisions: list[RegionDecision]

    @property
    def accepted(self):
        return [d for d in self.decisions if d.accepted]

    def render(self) -> str:
        lines = [f"Aira report — {self.workload!r} (prompt: {PROMPT!r})"]
        lines += [d.summary() for d in self.decisions]
        return "\n".join(lines)


class Aira:
    """The adviser: a tool pipeline plus a policy, per the spec."""

    def __init__(
        self,
        hw: HwModel | None = None,
        gate_threshold: float = 0.02,
        policy: AdviserPolicy | None = None,
        tools=DEFAULT_TOOLS,
    ):
        self.hw = hw or HwModel()
        self.model = OverlapModel(self.hw)
        self.gate_threshold = gate_threshold
        self.spec = AIRA_SPEC
        self.pipeline = ToolPipeline(tools=tools, policy=policy or SpecPolicy())

    # ------------------------------------------------------------------
    def advise(self, workload: Workload) -> AdviceReport:
        decisions = [self._advise_region(r) for r in workload.regions]
        return AdviceReport(workload=workload.name, decisions=decisions)

    def _advise_region(self, region: Region) -> RegionDecision:
        ctx = ToolContext(
            hw=self.hw, model=self.model, gate_threshold=self.gate_threshold
        )
        return self.pipeline.run(region, ctx)
