"""Aira: the end-to-end parallelization adviser (paper §V).

``Aira.advise(workload)`` executes the specification stages
(core/spec.py) over a workload's annotated regions:

  profile → annotate → static deps → dynamic deps → simulate (gate)
  → restructure with the Relic analogue

The paper drives these stages with Claude Sonnet 4 inside Cursor via MCP
tools; the tool surface here is identical (profiler / deps / overlap
simulator / relic restructurer) and the decision policy is the spec's
deterministic rules, swappable via ``AdviserPolicy`` — see DESIGN.md §2
for why the base model is not the contribution being reproduced.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import deps as deps_mod
from repro.core.overlap_model import HwModel, Microtask, OverlapModel, gate
from repro.core.relic import RelicSchedule, choose_schedule, relic_pfor
from repro.core.spec import AIRA_SPEC, PROMPT


@dataclass
class Region:
    """An annotated candidate region inside a hotspot function."""

    name: str
    fn: Callable  # per-item function
    items: Any  # pytree, leading axis = work items
    task_flops: float  # per-item FLOPs (napkin/profile derived)
    task_bytes: float  # per-item bytes moved
    task_chain: int = 0  # dependent accesses per item (tree/list hops)
    vector: bool = True  # pointer-chasing/gather regions are VPU-bound
    trace: Optional[deps_mod.MemoryTrace] = None
    restructure: Optional[Callable] = None  # custom parallel impl
    force: bool = False  # bypass the gate (paper's 1-Hop/BVH case)


@dataclass
class Workload:
    name: str
    serial_fn: Callable  # () -> result (the latency-critical step)
    regions: list[Region] = field(default_factory=list)


@dataclass
class RegionDecision:
    region: str
    stage_log: list[str]
    accepted: bool
    schedule: Optional[RelicSchedule]
    predicted_gain: float
    parallel_fn: Optional[Callable] = None

    def summary(self) -> str:
        s = "ACCEPT" if self.accepted else "reject"
        sched = self.schedule.describe() if self.schedule else "-"
        return f"[{s}] {self.region:20s} {sched:40s} | " + " ; ".join(self.stage_log)


@dataclass
class AdviceReport:
    workload: str
    decisions: list[RegionDecision]

    @property
    def accepted(self):
        return [d for d in self.decisions if d.accepted]

    def render(self) -> str:
        lines = [f"Aira report — {self.workload!r} (prompt: {PROMPT!r})"]
        lines += [d.summary() for d in self.decisions]
        return "\n".join(lines)


class Aira:
    def __init__(self, hw: HwModel | None = None, gate_threshold: float = 0.02):
        self.hw = hw or HwModel()
        self.model = OverlapModel(self.hw)
        self.gate_threshold = gate_threshold
        self.spec = AIRA_SPEC

    # ------------------------------------------------------------------
    def advise(self, workload: Workload) -> AdviceReport:
        decisions = []
        for region in workload.regions:
            decisions.append(self._advise_region(region))
        return AdviceReport(workload=workload.name, decisions=decisions)

    def _advise_region(self, region: Region) -> RegionDecision:
        log: list[str] = []
        n_items = jax.tree.leaves(region.items)[0].shape[0]

        # -- static dependence (BOLT analogue) --------------------------
        sample = jax.tree.map(lambda a: a[0], region.items)
        srep = deps_mod.static_deps(region.fn, sample)
        log.append(f"static: {srep.summary()}")

        # -- dynamic dependence (DynamoRIO analogue) ---------------------
        if region.trace is not None:
            conflict, why = deps_mod.check_conflicts(region.trace, n_tasks=2)
            log.append(f"dynamic: {why}")
            if conflict and not region.force:
                return RegionDecision(
                    region.name, log, False, None, 0.0, None
                )
        elif not srep.trivially_parallel and not region.force:
            log.append("dynamic: no trace supplied for non-trivial region → reject")
            return RegionDecision(region.name, log, False, None, 0.0, None)

        # -- SMT-aware simulation (Sniper gate) --------------------------
        schedule = choose_schedule(
            self.model,
            region.task_flops,
            region.task_bytes,
            n_items,
            chain=region.task_chain,
            vector=region.vector,
        )
        pred = schedule.prediction
        ok, why = gate(pred, self.gate_threshold)
        log.append(f"simulate: {why} (serial {pred.serial*1e6:.1f}µs, "
                   f"smt2 {pred.smt2*1e6:.1f}µs, smp2 {pred.smp2*1e6:.1f}µs)")
        if schedule.strategy == "serial" and not region.force:
            return RegionDecision(region.name, log, False, schedule, pred.gain("smt2"), None)
        if not ok and not region.force:
            return RegionDecision(region.name, log, False, schedule, pred.gain("smt2"), None)
        if region.force:
            log.append("force=True: gate bypassed (paper's 1-Hop/BVH scenario)")
            if schedule.strategy == "serial":
                schedule = RelicSchedule(
                    granularity=max(1, n_items // 2),
                    n_streams=2,
                    strategy="smt2",
                    prediction=pred,
                )

        # -- restructure (Relic analogue) --------------------------------
        if region.restructure is not None:
            parallel_fn = region.restructure
            log.append("restructure: custom Relic implementation")
        else:
            g, fn, items = schedule.granularity, region.fn, region.items
            parallel_fn = lambda: relic_pfor(fn, items, granularity=g)
            log.append(f"restructure: relic_pfor(gran={g})")
        return RegionDecision(
            region.name, log, True, schedule, pred.gain(schedule.strategy), parallel_fn
        )
