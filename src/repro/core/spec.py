"""The Aira specification file (§V.2 of the paper), machine-readable.

The paper ships a Markdown spec that an MCP tool loads into the LLM's
context; it describes the end-to-end flow ("Parallelize this program with
Aira") and embeds 20 worked examples of the Relic API so a general-purpose
model can restructure code onto a custom framework. Here the spec is a
dataclass the (deterministic) adviser executes stage by stage, and the 20
examples are *runnable* — the test suite asserts each one restructures
correctly under ``relic_pfor`` (i.e. matches its vmap semantics).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

PROMPT = "Parallelize this program with Aira"


@dataclass(frozen=True)
class Stage:
    name: str
    tool: str
    description: str
    reject_on: Optional[str] = None


AIRA_SPEC = (
    Stage(
        "profile",
        "core.profiler.profile_step",
        "Collect a sampled profile (perf+LBR analogue: compiled-HLO cost "
        "analysis); emit hot functions ranked by modeled time.",
    ),
    Stage(
        "annotate",
        "core.adviser.Aira.annotate",
        "Mark promising regions inside hotspot functions; record the "
        "region→source mapping.",
    ),
    Stage(
        "static_deps",
        "core.deps.static_deps",
        "BOLT analogue: jaxpr def-use walk; loop-carried state or scatter "
        "writes inside a region demand a dynamic check.",
        reject_on="irreducible loop-carried dependence",
    ),
    Stage(
        "dynamic_deps",
        "core.deps.check_conflicts",
        "DynamoRIO analogue: replay recorded gather/scatter index traces "
        "under the proposed task partition.",
        reject_on="cross-task write conflict",
    ),
    Stage(
        "simulate",
        "core.overlap_model.OverlapModel.predict",
        "Sniper analogue: price serial vs smt2 (co-scheduled pair on one "
        "core) vs smp2 (two cores).",
        reject_on="predicted smt2 gain ≤ 2%",
    ),
    Stage(
        "restructure",
        "core.relic.relic_pfor",
        "Rewrite accepted regions onto the Relic API with the granularity "
        "and stream count the simulator chose.",
    ),
)


# ---------------------------------------------------------------------------
# The serving-layer speculation flow (DESIGN.md §3.2): the same advisory
# shape as AIRA_SPEC — run a cheap helper stream, verify, commit only
# what survives, and gate the whole mechanism on a predicted win — one
# level up, at the decode step. Deliberately NOT part of AIRA_SPEC (the
# compute-region pipeline is pinned by its golden decisions); the
# ``speculate`` stage rides in DEFAULT_TOOLS but reports only for
# regions carrying a speculation measurement.

SERVING_SPEC = (
    Stage(
        "draft",
        "serve.speculative.DraftSource.propose",
        "Run the helper stream: K proposed tokens per live row, from the "
        "n-gram prompt-lookup drafter or a small draft model sharing the "
        "tokenizer space.",
    ),
    Stage(
        "verify",
        "models.model.Model.verify_step",
        "One fixed-K target forward over [pending token, K drafts]; "
        "greedy-equivalence acceptance compares each draft to the "
        "previous position's argmax.",
        reject_on="draft token != target argmax (suffix rejected)",
    ),
    Stage(
        "rollback",
        "serve.kv_cache.PagedKVCache.truncate_row",
        "Rewind rejected entries (SlotKVCache.truncate_row likewise): "
        "committed lengths drop, claimed tail blocks release back to "
        "their reservation; shared prefix blocks are never touched.",
    ),
    Stage(
        "speculate",
        "core.tools.SpeculationAdvisorTool",
        "Price expected per-output-token latency from measured draft "
        "cost + acceptance rate; pick K in {0, 2, 4, 8} per workload.",
        reject_on="predicted gain <= threshold → K=0",
    ),
)


# ---------------------------------------------------------------------------
# The 20 Relic usage examples (paper §V.3). Each is (per-item fn, item
# maker) — restructured with relic_pfor and asserted equal to vmap(fn).


def _items(shape, dtype=jnp.float32, seed=0):
    return jax.random.normal(jax.random.key(seed), shape, dtype)


RELIC_EXAMPLES: list[dict] = [
    dict(name="scale", fn=lambda x: 2.0 * x, items=lambda: _items((64, 8))),
    dict(name="saxpy", fn=lambda ab: ab[0] * 1.5 + ab[1],
         items=lambda: (_items((64, 8)), _items((64, 8), seed=1))),
    dict(name="dot", fn=lambda xy: jnp.dot(xy[0], xy[1]),
         items=lambda: (_items((32, 16)), _items((32, 16), seed=1))),
    dict(name="norm", fn=lambda x: x / (jnp.linalg.norm(x) + 1e-6),
         items=lambda: _items((48, 12))),
    dict(name="relu_mlp", fn=lambda x: jax.nn.relu(x @ jnp.ones((8, 4))),
         items=lambda: _items((64, 8))),
    dict(name="softmax_row", fn=jax.nn.softmax, items=lambda: _items((40, 10))),
    dict(name="cumsum_row", fn=jnp.cumsum, items=lambda: _items((40, 10))),
    dict(name="sort_row", fn=jnp.sort, items=lambda: _items((32, 16))),
    dict(name="topk_row", fn=lambda x: jax.lax.top_k(x, 4)[0],
         items=lambda: _items((32, 16))),
    dict(name="gather_reduce",
         fn=lambda xi: xi[0][xi[1]].sum(),
         items=lambda: (_items((32, 64)),
                        jax.random.randint(jax.random.key(2), (32, 8), 0, 64))),
    dict(name="stencil3",
         fn=lambda x: x - 0.5 * (jnp.roll(x, 1) + jnp.roll(x, -1)),
         items=lambda: _items((48, 16))),
    dict(name="poly_eval", fn=lambda x: ((x * 0.5 + 1.0) * x - 2.0) * x + 3.0,
         items=lambda: _items((64, 8))),
    dict(name="masked_sum", fn=lambda x: jnp.where(x > 0, x, 0.0).sum(),
         items=lambda: _items((64, 8))),
    dict(name="argmin_dist",
         fn=lambda q: jnp.argmin(jnp.sum((q[None, :] - jnp.eye(8)) ** 2, -1)),
         items=lambda: _items((40, 8))),
    dict(name="fixed_iter",
         fn=lambda x: jax.lax.fori_loop(0, 4, lambda i, v: 0.5 * (v + x / jnp.maximum(v, 1e-3)), x),
         items=lambda: jnp.abs(_items((64, 8))) + 1.0),
    dict(name="bincount8",
         fn=lambda i: jnp.zeros(8).at[i].add(1.0),
         items=lambda: jax.random.randint(jax.random.key(3), (32, 16), 0, 8)),
    dict(name="logsumexp_row", fn=jax.nn.logsumexp, items=lambda: _items((40, 10))),
    dict(name="l2_pair",
         fn=lambda xy: jnp.sum((xy[0] - xy[1]) ** 2),
         items=lambda: (_items((48, 12)), _items((48, 12), seed=4))),
    dict(name="clip_quant",
         fn=lambda x: jnp.round(jnp.clip(x, -1, 1) * 127).astype(jnp.int8),
         items=lambda: _items((64, 8))),
    dict(name="window_mean",
         fn=lambda x: jnp.convolve(x, jnp.ones(3) / 3.0, mode="same"),
         items=lambda: _items((32, 16))),
]
assert len(RELIC_EXAMPLES) == 20
