"""G. Limit Order Book (paper §VI.G).

Multi-symbol matching engine: 256 symbols, each with a 100-level
ascending price-level list holding per-level order queues; 500 order
updates per symbol per iteration. Items = symbols (disjoint books →
conflict-free across tasks; sequential chain within).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite.common import Benchmark, register
from repro.core.deps import MemoryTrace

N_SYMBOLS = 256
N_LEVELS = 100
N_UPDATES = 500
SEARCH_HOPS = 12  # skip-ish search budget per update


def build(seed=6):
    rng = np.random.default_rng(seed)
    # per symbol: level order (sorted ascending) as next-pointers
    nxt = np.tile(np.arange(1, N_LEVELS + 1, dtype=np.int32), (N_SYMBOLS, 1))
    nxt[:, -1] = -1
    qty = rng.integers(0, 50, (N_SYMBOLS, N_LEVELS)).astype(np.float32)
    updates_price = rng.integers(0, N_LEVELS, (N_SYMBOLS, N_UPDATES)).astype(np.int32)
    updates_qty = rng.integers(-5, 6, (N_SYMBOLS, N_UPDATES)).astype(np.float32)
    return {
        "nxt": jnp.asarray(nxt),
        "qty": jnp.asarray(qty),
        "up_p": jnp.asarray(updates_price),
        "up_q": jnp.asarray(updates_qty),
        "sym": jnp.arange(N_SYMBOLS, dtype=jnp.int32),
        "_np": {"up_p": updates_price},
    }


def item_fn(data):
    def fn(s):
        nxt = data["nxt"][s]

        def one_update(book, upd):
            price, dq = upd

            # linked search from best price toward `price` (bounded hops)
            def hop(n, _):
                nx = nxt[jnp.maximum(n, 0)]
                ok = jnp.logical_and(nx >= 0, nx <= price)
                return jnp.where(ok, nx, n), None

            lvl, _ = jax.lax.scan(hop, jnp.int32(0), None, length=SEARCH_HOPS)
            book = book.at[lvl].add(dq)
            book = jnp.maximum(book, 0.0)
            return book, None

        book, _ = jax.lax.scan(
            one_update, data["qty"][s], (data["up_p"][s], data["up_q"][s])
        )
        return book.sum()

    return fn


def items(data):
    return data["sym"]


def cost(data):
    # per symbol: 500 sequential updates × bounded search chain
    return dict(
        flops=N_UPDATES * 8.0,
        bytes=N_UPDATES * SEARCH_HOPS * 16.0,
        chain=N_UPDATES * SEARCH_HOPS // 4,
        vector=True,
    )


def trace(data) -> MemoryTrace:
    """Writes = (symbol, level) slots each task updates — disjoint across
    symbols, the conflict-free case the paper's checker must PASS."""
    up_p = data["_np"]["up_p"]
    reads, writes = [], []
    for s in range(N_SYMBOLS):
        lv = np.unique(up_p[s])
        addr = s * N_LEVELS + lv
        reads.append(addr)
        writes.append(addr)
    return MemoryTrace(reads=reads, writes=writes)


register(
    Benchmark(
        name="LOB",
        domain="high-frequency trading",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
        trace=trace,
    )
)
