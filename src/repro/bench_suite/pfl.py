"""PFL motion update (paper Fig. 1): the compute-bound sweep kernel.

Per-particle pose update from RTRBench's Particle Filter Localization:
trig-heavy floating-point work, no dependent loads — the kernel where
the paper measures only +5.1% (Relic-SMT) / +2.7% (OMP-SMT) at 1000
particles because one thread already keeps the FP ports mostly busy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlap_model import Microtask


def build(n_particles=1000, seed=10):
    rng = np.random.default_rng(seed)
    pose = rng.normal(size=(n_particles, 3)).astype(np.float32)  # x, y, θ
    noise = rng.normal(size=(n_particles, 3)).astype(np.float32)
    return {"pose": jnp.asarray(pose), "noise": jnp.asarray(noise),
            "v": jnp.float32(1.2), "w": jnp.float32(0.3), "dt": jnp.float32(0.05)}


def item_fn(data):
    v, w, dt = data["v"], data["w"], data["dt"]

    def fn(args):
        pose, eps = args
        x, y, th = pose[0], pose[1], pose[2]
        v_n = v + 0.1 * eps[0]
        w_n = w + 0.05 * eps[1]
        r = v_n / jnp.maximum(jnp.abs(w_n), 1e-4)
        x2 = x - r * jnp.sin(th) + r * jnp.sin(th + w_n * dt)
        y2 = y + r * jnp.cos(th) - r * jnp.cos(th + w_n * dt)
        th2 = th + w_n * dt + 0.02 * eps[2] * dt
        return jnp.stack([x2, y2, th2])

    return fn


def items(data):
    return (data["pose"], data["noise"])


def microtask() -> Microtask:
    # ~200 scalar FP ops (4 trig ≈ 40 ops each + arithmetic), 24B in/out
    return Microtask(flops=200.0, bytes=48.0, chain=0, vector=True)
