"""CC benchmark (paper Fig. 2): the memory-bound sweep kernel.

Fine-grained graph processing from the Relic paper [4]: one label-
propagation step of connected components — per vertex, gather the
labels of its neighbours (dependent random loads) and take the min.
This is the kernel whose SMT-Relic band the paper highlights: a range
of granularities where co-scheduling on one core beats both serial and
SMP while OpenMP loses to its own dispatch overhead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlap_model import Microtask

DEGREE = 8


def build(n_vertices=4096, seed=11):
    rng = np.random.default_rng(seed)
    neigh = rng.integers(0, n_vertices, (n_vertices, DEGREE)).astype(np.int32)
    labels = np.arange(n_vertices, dtype=np.int32)
    return {"neigh": jnp.asarray(neigh), "labels": jnp.asarray(labels),
            "verts": jnp.arange(n_vertices, dtype=jnp.int32)}


def item_fn(data):
    labels, neigh = data["labels"], data["neigh"]

    def fn(v):
        ls = labels[neigh[v]]  # DEGREE dependent random loads
        return jnp.minimum(jnp.min(ls), labels[v])

    return fn


def items(data):
    return data["verts"]


def microtask() -> Microtask:
    # per vertex: DEGREE random label loads behind one adjacency load
    return Microtask(flops=3.0 * DEGREE, bytes=DEGREE * 68.0, chain=3, vector=True)
