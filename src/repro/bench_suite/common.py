"""Shared benchmark scaffolding + array-encoded linked data structures.

Each paper benchmark provides:
  build(key)        → data dict (index-array encoded linked structures)
  items(data)       → leading-axis work items of one iteration
  item_fn(data)     → per-item function (the annotated region)
  cost(data)        → per-item Microtask parameters (flops, bytes, chain)
  trace(data)       → MemoryTrace of dynamic accesses (DynamoRIO analogue)
  realized_* fields → the Relic-API granularity floor + locality penalty
                      used when a region is force-parallelized below its
                      band (paper's 1-Hop/BVH outcome)

Pointer-chasing on TPU: linked structures are index arrays, traversals
are bounded ``lax.scan``/``while_loop`` over node indices (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

BENCHMARKS: dict[str, "Benchmark"] = {}


@dataclass
class Benchmark:
    name: str
    domain: str
    build: Callable
    items: Callable
    item_fn: Callable
    cost: Callable  # data -> dict(flops, bytes, chain, vector)
    trace: Optional[Callable] = None
    combine: str = "sum"
    # paper §VII outcome modeling:
    force: bool = False  # 1-Hop/BVH: applied despite the band
    realized_granularity: int = 0  # Relic API floor when forced (0 = free)
    locality_penalty: float = 0.0  # chain/bytes inflation when forced

    def region(self, data, combine: str | None = None):
        """The benchmark's annotated region, ready for the tool pipeline.
        combine=None → the benchmark's declared combine mode."""
        from repro.core.adviser import Region

        c = self.cost(data)
        return Region(
            name=self.name,
            fn=self.item_fn(data),
            items=self.items(data),
            task_flops=c["flops"],
            task_bytes=c["bytes"],
            task_chain=c["chain"],
            vector=c.get("vector", True),
            trace=self.trace(data) if self.trace else None,
            force=self.force,
            combine=self.combine if combine is None else combine,
        )

    def workload(self, data, combine: str | None = None):
        from repro.core.adviser import Workload

        return Workload(
            name=self.name,
            serial_fn=lambda: self.serial_value(data, combine=combine),
            regions=[self.region(data, combine=combine)],
        )

    def serial_value(self, data, combine: str | None = None):
        """One measurement iteration, serial semantics. combine="sum"
        reduces per-item results the way the declared region does."""
        fn = self.item_fn(data)
        its = self.items(data)
        out = jax.lax.map(fn, its)
        if combine == "sum":
            return jax.tree.map(lambda y: y.sum(axis=0), out)
        return out

    def parallel_value(self, data, granularity=8, combine: str | None = None):
        """The restructured iteration, through the cached plan layer.

        combine=None → "stack" (item order preserved, elementwise-
        comparable to serial_value). Under an outer trace the plan cache
        is bypassed — caching a closure over tracers would leak them.
        """
        from repro import compat
        from repro.core import plan as plan_mod
        from repro.core.relic import relic_pfor

        fn = self.item_fn(data)
        its = self.items(data)
        comb = combine or "stack"
        if any(compat.is_tracer(l) for l in jax.tree.leaves((its, data))):
            return relic_pfor(fn, its, granularity=granularity, combine=comb)
        plan = plan_mod.plan_for(
            self.name,
            fn,
            its,
            granularity=granularity,
            combine=comb,
            salt=plan_mod.data_fingerprint(data),
        )
        return plan.execute(its)


def register(b: Benchmark) -> Benchmark:
    BENCHMARKS[b.name] = b
    return b


# ---------------------------------------------------------------------------
# array-encoded structures (numpy build side)


def build_kdtree(points: np.ndarray):
    """Balanced KD-tree as arrays: returns dict(point, left, right, axis)."""
    n = len(points)
    left = np.full(n, -1, np.int32)
    right = np.full(n, -1, np.int32)
    axis = np.zeros(n, np.int32)
    pts = np.asarray(points)
    order = np.empty(n, np.int32)  # tree-node id -> point id
    slot = [0]

    def rec(idx, depth):
        if len(idx) == 0:
            return -1
        ax = depth % pts.shape[1]
        idx = idx[np.argsort(pts[idx, ax], kind="stable")]
        mid = len(idx) // 2
        me = slot[0]
        slot[0] += 1
        order[me] = idx[mid]
        axis[me] = ax
        l = rec(idx[:mid], depth + 1)
        r = rec(idx[mid + 1 :], depth + 1)
        left[me], right[me] = l, r
        return me

    root = rec(np.arange(n, dtype=np.int64), 0)
    return {
        "point": pts[order],
        "left": left,
        "right": right,
        "axis": axis,
        "root": np.int32(root),
        "perm": order,
    }


def build_bst(keys: np.ndarray, values: np.ndarray):
    """Balanced BST over sorted keys (arrays left/right/key/value)."""
    order = np.argsort(keys)
    keys, values = np.asarray(keys)[order], np.asarray(values)[order]
    n = len(keys)
    left = np.full(n, -1, np.int32)
    right = np.full(n, -1, np.int32)
    okey = np.empty_like(keys)
    oval = np.empty_like(values)
    slot = [0]

    def rec(lo, hi):
        if lo >= hi:
            return -1
        mid = (lo + hi) // 2
        me = slot[0]
        slot[0] += 1
        okey[me], oval[me] = keys[mid], values[mid]
        left[me] = rec(lo, mid)
        right[me] = rec(mid + 1, hi)
        return me

    root = rec(0, n)
    return {"key": okey, "value": oval, "left": left, "right": right, "root": np.int32(root)}


def build_linked_lists(rng, n_lists: int, min_len: int, max_len: int):
    """Pool of singly linked lists: head[i] → chain via nxt, payload val."""
    lens = rng.integers(min_len, max_len + 1, n_lists)
    total = int(lens.sum())
    nxt = np.full(total, -1, np.int32)
    val = rng.normal(size=total).astype(np.float32)
    head = np.zeros(n_lists, np.int32)
    pos = 0
    perm = rng.permutation(total).astype(np.int32)  # scatter nodes (cache-hostile)
    for i, L in enumerate(lens):
        ids = perm[pos : pos + L]
        head[i] = ids[0]
        for a, b in zip(ids[:-1], ids[1:]):
            nxt[a] = b
        pos += L
    return {"head": head, "nxt": nxt, "val": val, "len": lens.astype(np.int32)}


def bst_lookup(bst, key, depth: int):
    """Fixed-depth BST search (bounded scan — TPU-honest traversal)."""

    def step(node, _):
        k = bst["key"][jnp.maximum(node, 0)]
        go_left = key < k
        nxt = jnp.where(go_left, bst["left"][jnp.maximum(node, 0)], bst["right"][jnp.maximum(node, 0)])
        hit = jnp.logical_and(node >= 0, k == key)
        keep = jnp.where(hit, node, -1)
        node = jnp.where(node < 0, node, nxt)
        return node, keep

    _, hits = jax.lax.scan(step, bst["root"], None, length=depth)
    found = jnp.max(hits)
    return found  # node id or -1


def list_sum(lists, head, max_hops: int):
    """Traverse one linked list, summing payloads (dependent loads)."""

    def step(carry, _):
        node, acc = carry
        v = jnp.where(node >= 0, lists["val"][jnp.maximum(node, 0)], 0.0)
        nxt = jnp.where(node >= 0, lists["nxt"][jnp.maximum(node, 0)], -1)
        return (nxt, acc + v), None

    (_, acc), _ = jax.lax.scan(step, (head, 0.0), None, length=max_hops)
    return acc
