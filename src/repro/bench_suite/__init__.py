"""The paper's 10 latency-critical benchmarks (§VI) in JAX, plus the
granularity-sweep kernels of Figs. 1–2 (pfl, cc)."""
from repro.bench_suite.common import BENCHMARKS, Benchmark, register  # noqa: F401
from repro.bench_suite import (  # noqa: F401,E402
    geospatial,
    vwap,
    lidar,
    timeline,
    rf,
    onehop,
    lob,
    geoip,
    fraud,
    bvh,
)
