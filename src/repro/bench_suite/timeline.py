"""D. Social Media Feed Generation (paper §VI.D).

Social-graph traversal: collect candidate posts from followed accounts,
score by engagement × temporal decay, keep top-8 per account.
1000 accounts, 64–192 follows, 16–80 posts each, 5–25 reactions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite.common import Benchmark, register

N_ACCOUNTS = 1000
MAX_FOLLOW = 192
MAX_POSTS = 80
TOP_POSTS = 8


def build(seed=3):
    rng = np.random.default_rng(seed)
    n_follow = rng.integers(64, 193)
    follows = rng.choice(N_ACCOUNTS, size=MAX_FOLLOW, replace=True).astype(np.int32)
    follow_mask = (np.arange(MAX_FOLLOW) < n_follow).astype(np.float32)
    n_posts = rng.integers(16, 81, N_ACCOUNTS)
    ts = rng.uniform(0, 24.0, (N_ACCOUNTS, MAX_POSTS)).astype(np.float32)
    reactions = rng.integers(5, 26, (N_ACCOUNTS, MAX_POSTS)).astype(np.float32)
    post_mask = (np.arange(MAX_POSTS)[None, :] < n_posts[:, None]).astype(np.float32)
    return {
        "follows": jnp.asarray(follows),
        "follow_mask": jnp.asarray(follow_mask),
        "ts": jnp.asarray(ts),
        "reactions": jnp.asarray(reactions),
        "post_mask": jnp.asarray(post_mask),
    }


def item_fn(data):
    ts, reactions, post_mask = data["ts"], data["reactions"], data["post_mask"]

    def fn(args):
        acct, fmask = args
        t = ts[acct]
        score = reactions[acct] * jnp.exp(-0.15 * (24.0 - t)) * post_mask[acct]
        top = jax.lax.top_k(score, TOP_POSTS)[0]
        return fmask * top.sum()

    return fn


def items(data):
    return (data["follows"], data["follow_mask"])


def cost(data):
    # per followed account: 80-post gather + exp/score + top-k
    return dict(
        flops=MAX_POSTS * 8.0 + MAX_POSTS * np.log2(MAX_POSTS),
        bytes=MAX_POSTS * 12.0 + 64.0,
        chain=2,
        vector=True,
    )


register(
    Benchmark(
        name="Timeline",
        domain="social media",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
    )
)
