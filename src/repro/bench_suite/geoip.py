"""H. IP Address Geolocation (paper §VI.H).

Binary trie over IPv4 prefixes: each node tests one bit; longest-prefix
match returns a location id. Items = a batch of IP lookups (paper: 10⁶
per iteration; scaled to 8192 for CPU wall-clock runs — structure and
per-item cost are unchanged).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite.common import Benchmark, register

N_PREFIXES = 4096
N_IPS = 8192
MAX_DEPTH = 24


def build(seed=7):
    rng = np.random.default_rng(seed)
    # insert random prefixes (8..24 bits) into an array trie
    left = [-1]
    right = [-1]
    value = [0]

    def insert(prefix, plen, val):
        node = 0
        for d in range(plen):
            bit = (prefix >> (31 - d)) & 1
            child = right[node] if bit else left[node]
            if child == -1:
                left.append(-1)
                right.append(-1)
                value.append(value[node])
                child = len(left) - 1
                if bit:
                    right[node] = child
                else:
                    left[node] = child
            node = child
        value[node] = val

    for i in range(N_PREFIXES):
        plen = int(rng.integers(8, MAX_DEPTH + 1))
        prefix = int(rng.integers(0, 2**32)) & (~((1 << (32 - plen)) - 1))
        insert(prefix, plen, int(rng.integers(1, 256)))

    ips = rng.integers(0, 2**32, N_IPS, dtype=np.uint32).astype(np.int64)
    return {
        "left": jnp.asarray(np.asarray(left, np.int32)),
        "right": jnp.asarray(np.asarray(right, np.int32)),
        "value": jnp.asarray(np.asarray(value, np.int32)),
        "ips": jnp.asarray(ips),
    }


def item_fn(data):
    left, right, value = data["left"], data["right"], data["value"]

    def fn(ip):
        def step(carry, d):
            node, best = carry
            bit = (ip >> (31 - d)) & 1
            nxt = jnp.where(bit == 1, right[jnp.maximum(node, 0)], left[jnp.maximum(node, 0)])
            best = jnp.where(node >= 0, value[jnp.maximum(node, 0)], best)
            node = jnp.where(node < 0, node, nxt)
            return (node, best), None

        (_, best), _ = jax.lax.scan(
            step, (jnp.int32(0), jnp.int32(0)), jnp.arange(MAX_DEPTH)
        )
        return best

    return fn


def items(data):
    return data["ips"]


def cost(data):
    return dict(flops=MAX_DEPTH * 3.0, bytes=MAX_DEPTH * 16.0, chain=MAX_DEPTH, vector=True)


register(
    Benchmark(
        name="GeoIP",
        domain="CDN / edge",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
    )
)
