"""B. Volume-Weighted Average Price Engine (paper §VI.B).

Skip-list price-level search (4 levels) → linked-list volume aggregation
→ sliding-window VWAP over a 32-tick ring buffer. 100 price levels
($100.00–$100.99, 1¢ ticks), 30 trade messages per iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite import common
from repro.bench_suite.common import Benchmark, register
from repro.core.deps import MemoryTrace

N_LEVELS = 100
SKIP_LEVELS = 4
N_MSGS = 30
WINDOW = 32
VOL_HOPS = 10
HOPS_PER_LEVEL = 6


def build(seed=1):
    rng = np.random.default_rng(seed)
    # skip list: level k links over sorted price levels with stride ~2^k
    nxt = np.zeros((SKIP_LEVELS, N_LEVELS), np.int32)
    for k in range(SKIP_LEVELS):
        stride = 2**k
        for i in range(N_LEVELS):
            nxt[k, i] = i + stride if i + stride < N_LEVELS else -1
    vol_lists = common.build_linked_lists(rng, N_LEVELS, 3, VOL_HOPS - 2)
    ring = rng.uniform(100.0, 101.0, (WINDOW,)).astype(np.float32)
    ring_vol = rng.uniform(1, 100, (WINDOW,)).astype(np.float32)
    msgs = rng.integers(0, 100, (N_MSGS,)).astype(np.int32)  # price ticks
    return {
        "nxt": jnp.asarray(nxt),
        "lists": {k: jnp.asarray(v) for k, v in vol_lists.items()},
        "ring": jnp.asarray(ring),
        "ring_vol": jnp.asarray(ring_vol),
        "msgs": msgs,
        "_np": {"nxt": nxt, "msgs": msgs},
    }


def _skip_search(nxt, target):
    """Top-down skip-list search for `target` level (dependent hops)."""
    node = jnp.int32(0)
    for k in reversed(range(SKIP_LEVELS)):

        def hop(carry, _):
            n = carry
            nx = nxt[k, jnp.maximum(n, 0)]
            ok = jnp.logical_and(nx >= 0, nx <= target)
            return jnp.where(ok, nx, n), None

        node, _ = jax.lax.scan(hop, node, None, length=HOPS_PER_LEVEL)
    return node


def item_fn(data):
    nxt, lists = data["nxt"], data["lists"]
    ring, ring_vol = data["ring"], data["ring_vol"]

    def fn(args):
        price_tick, slot = args
        level = _skip_search(nxt, price_tick)
        vol = common.list_sum(lists, lists["head"][level], VOL_HOPS)
        # sliding-window VWAP: each message appends at its own ring slot
        w = ring_vol.at[slot % WINDOW].add(vol)
        vwap = jnp.sum(ring * w) / jnp.maximum(jnp.sum(w), 1e-6)
        return vwap

    return fn


def items(data):
    return (data["msgs"], jnp.arange(N_MSGS, dtype=jnp.int32))


def cost(data):
    chain = SKIP_LEVELS * HOPS_PER_LEVEL + VOL_HOPS
    return dict(
        flops=3.0 * WINDOW + 20.0, bytes=chain * 64.0 + WINDOW * 8.0,
        chain=chain, vector=True,
    )


def trace(data) -> MemoryTrace:
    nxt, msgs = data["_np"]["nxt"], data["_np"]["msgs"]
    reads, writes = [], []
    for i, t in enumerate(msgs):
        node, visited = 0, [0]
        for k in reversed(range(SKIP_LEVELS)):
            for _ in range(HOPS_PER_LEVEL):
                nx = nxt[k, node]
                if 0 <= nx <= t:
                    node = int(nx)
                    visited.append(node)
        reads.append(np.asarray(visited))
        # ring slots live in their own address range (disjoint across a
        # round-robin 2-task split)
        writes.append(np.asarray([10_000_000 + i % WINDOW]))
    return MemoryTrace(reads=reads, writes=writes)


register(
    Benchmark(
        name="VWAP",
        domain="high-frequency trading",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
        trace=trace,
    )
)
