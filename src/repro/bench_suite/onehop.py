"""F. Graph Neural Network 1-Hop Embedding (paper §VI.F).

1-hop neighbour aggregation for node 0: 200 000 nodes, average degree
256, 64 features per node. Per-neighbour work is a single feature-row
gather + MAC — far below the Relic granularity floor, which is why the
paper measures a −9% regression when it is force-parallelized (§VII).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite.common import Benchmark, register

N_NODES = 200_000
DEGREE = 256
N_FEAT = 64


def build(seed=5):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(N_NODES, N_FEAT)).astype(np.float32)
    neigh = rng.choice(N_NODES, size=DEGREE, replace=False).astype(np.int32)
    w = rng.normal(size=(N_FEAT,)).astype(np.float32) / np.sqrt(N_FEAT)
    return {"feats": jnp.asarray(feats), "neigh": jnp.asarray(neigh), "w": jnp.asarray(w)}


def item_fn(data):
    feats, w = data["feats"], data["w"]

    def fn(n):
        return jnp.dot(feats[n], w)  # gather one row + 64-MAC

    return fn


def items(data):
    return data["neigh"]


def cost(data):
    return dict(flops=2.0 * N_FEAT, bytes=N_FEAT * 4.0 + 8.0, chain=1, vector=True)


register(
    Benchmark(
        name="1-Hop",
        domain="GNN inference",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
        force=True,  # paper: not flagged by the simulator, but below the
        realized_granularity=8,  # Relic API floor when applied
        locality_penalty=0.3,
    )
)
