"""J. 3D Collision Detection (paper §VI.J).

BVH descent per trajectory point over an obstacle point cloud in a 1 km
cube. Paper scale: 2·10⁵ obstacles, 10⁴ trajectory points (scaled to
65 536 / 2048 for CPU wall-clock; structure unchanged).

Per-point descent alternates one AABB overlap test (compute) with one
child fetch (dependent load) — the paper reports a −61% regression when
this kernel is force-parallelized below the Relic granularity floor
(§VII, Fig. 4): the microtasks are too fine and the split breaks the
descent's cache locality.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite import common
from repro.bench_suite.common import Benchmark, register

N_OBST = 65_536
N_TRAJ = 2048
VISIT_BUDGET = 40


def build(seed=9):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1000, (N_OBST, 3)).astype(np.float32)
    kd = common.build_kdtree(pts)  # KD doubles as a BVH with point AABBs
    traj = rng.uniform(200, 800, (N_TRAJ, 3)).astype(np.float32)
    return {"kd": {k: jnp.asarray(v) for k, v in kd.items()}, "traj": jnp.asarray(traj)}


def item_fn(data):
    kd = data["kd"]

    def fn(p):
        def step(carry, _):
            stack, sp, best = carry
            has = sp > 0
            node = jnp.where(has, stack[jnp.maximum(sp - 1, 0)], -1)
            sp = jnp.where(has, sp - 1, sp)
            nv = jnp.maximum(node, 0)
            pt = kd["point"][nv]
            d2 = jnp.sum((pt - p) ** 2)  # AABB/sphere overlap test
            best = jnp.where(jnp.logical_and(node >= 0, d2 < best), d2, best)
            ax = kd["axis"][nv]
            diff = p[ax] - pt[ax]
            near = jnp.where(diff < 0, kd["left"][nv], kd["right"][nv])
            far = jnp.where(diff < 0, kd["right"][nv], kd["left"][nv])
            push_far = jnp.logical_and(
                jnp.logical_and(node >= 0, far >= 0), diff * diff < best
            )
            stack = jnp.where(push_far, stack.at[sp].set(far), stack)
            sp = sp + push_far.astype(jnp.int32)
            push_near = jnp.logical_and(node >= 0, near >= 0)
            stack = jnp.where(push_near, stack.at[sp].set(near), stack)
            sp = sp + push_near.astype(jnp.int32)
            return (stack, sp, best), None

        stack0 = jnp.zeros((48,), jnp.int32).at[0].set(kd["root"])
        (_, _, best), _ = jax.lax.scan(
            step, (stack0, jnp.int32(1), jnp.float32(1e9)), None, length=VISIT_BUDGET
        )
        return jnp.sqrt(best)

    return fn


def items(data):
    return data["traj"]


def cost(data):
    return dict(
        flops=VISIT_BUDGET * 10.0, bytes=VISIT_BUDGET * 64.0,
        chain=VISIT_BUDGET, vector=True,
    )


register(
    Benchmark(
        name="BVH",
        domain="aerospace / robotics",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
        force=True,  # paper: passed the gate but below the Relic floor
        realized_granularity=1,
        locality_penalty=2.5,
    )
)
