"""I. Fraud Detection (paper §VI.I).

5-vertex fan-in motif detection: for each tested edge (u→v), scan v's
in-neighbour list for ≥4 distinct sources within a recency window.
10⁵ vertices, 3·10⁵ background edges, 1000 tested edges per iteration.

This is the benchmark the paper's Sniper gate REJECTS: the per-edge scan
streams the adjacency list (bandwidth-bound, negligible dependent-chain
and compute), so co-scheduling cannot hide anything — predicted gain
≤ gate threshold → Relic is not applied, performance unchanged (§VII).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite.common import Benchmark, register
from repro.core.deps import MemoryTrace

N_VERTS = 100_000
N_EDGES = 300_000
N_TESTS = 1000
MAX_IN = 64  # padded in-neighbour window scanned per test


def build(seed=8):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, N_VERTS, N_EDGES).astype(np.int32)
    dst = rng.integers(0, N_VERTS, N_EDGES).astype(np.int32)
    ts = rng.uniform(0, 1, N_EDGES).astype(np.float32)
    # CSR of in-edges, padded per-vertex window
    order = np.argsort(dst, kind="stable")
    dst_s, src_s, ts_s = dst[order], src[order], ts[order]
    starts = np.searchsorted(dst_s, np.arange(N_VERTS))
    counts = np.diff(np.append(starts, N_EDGES))
    in_pad = np.zeros((N_VERTS, 1), np.int32)  # stored compact: window table
    window_src = np.full((N_VERTS, MAX_IN), -1, np.int32)
    window_ts = np.zeros((N_VERTS, MAX_IN), np.float32)
    for v in np.unique(dst_s):
        c = min(int(counts[v]), MAX_IN)
        window_src[v, :c] = src_s[starts[v] : starts[v] + c]
        window_ts[v, :c] = ts_s[starts[v] : starts[v] + c]
    tests = rng.integers(0, N_EDGES, N_TESTS).astype(np.int32)
    return {
        "win_src": jnp.asarray(window_src),
        "win_ts": jnp.asarray(window_ts),
        "src": jnp.asarray(src),
        "dst": jnp.asarray(dst),
        "ts": jnp.asarray(ts),
        "tests": jnp.asarray(tests),
        "_np": {"dst": dst, "tests": tests},
    }


def item_fn(data):
    def fn(e):
        v = data["dst"][e]
        t0 = data["ts"][e]
        srcs = data["win_src"][v]  # streamed scan (bandwidth-bound)
        tss = data["win_ts"][v]
        recent = jnp.logical_and(srcs >= 0, jnp.abs(tss - t0) < 0.1)
        distinct = jnp.logical_and(recent, srcs != data["src"][e])
        fan_in = distinct.sum()
        return (fan_in >= 4).astype(jnp.float32)

    return fn


def items(data):
    return data["tests"]


def cost(data):
    # stream 64 in-edges, each on its own cold cache line (8B useful per
    # 64B line): pure bandwidth, negligible compute, no dependent chain
    return dict(flops=float(MAX_IN), bytes=MAX_IN * 64.0, chain=0, vector=True)


def trace(data) -> MemoryTrace:
    dst, tests = data["_np"]["dst"], data["_np"]["tests"]
    reads = [np.arange(int(dst[e]) * MAX_IN, int(dst[e]) * MAX_IN + MAX_IN) for e in tests]
    writes = [np.asarray([], np.int64) for _ in tests]
    return MemoryTrace(reads=reads, writes=writes)


register(
    Benchmark(
        name="Fraud",
        domain="fraud detection",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
        trace=trace,
    )
)
