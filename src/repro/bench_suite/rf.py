"""E. Random Forest (paper §VI.E).

256 binary decision trees of depth 5 as linked node structures;
32-feature input vector; ensemble average.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite.common import Benchmark, register

N_TREES = 256
DEPTH = 5
N_FEAT = 32
N_NODES = 2 ** (DEPTH + 1) - 1  # full binary tree, 63 nodes


def build(seed=4):
    rng = np.random.default_rng(seed)
    feat = rng.integers(0, N_FEAT, (N_TREES, N_NODES)).astype(np.int32)
    thr = rng.normal(size=(N_TREES, N_NODES)).astype(np.float32)
    leaf = rng.normal(size=(N_TREES, N_NODES)).astype(np.float32)
    # children laid out randomly (pointer-style, not implicit heap order)
    left = np.zeros((N_TREES, N_NODES), np.int32)
    right = np.zeros((N_TREES, N_NODES), np.int32)
    root = np.zeros((N_TREES,), np.int32)
    for t in range(N_TREES):
        perm = rng.permutation(N_NODES).astype(np.int32)
        heap_l = np.where(2 * np.arange(N_NODES) + 1 < N_NODES, 2 * np.arange(N_NODES) + 1, 0)
        heap_r = np.where(2 * np.arange(N_NODES) + 2 < N_NODES, 2 * np.arange(N_NODES) + 2, 0)
        inv = np.argsort(perm)
        left[t][perm] = perm[heap_l]
        right[t][perm] = perm[heap_r]
        root[t] = perm[0]
    x = rng.normal(size=(N_FEAT,)).astype(np.float32)
    return {
        "feat": jnp.asarray(feat), "thr": jnp.asarray(thr), "leaf": jnp.asarray(leaf),
        "left": jnp.asarray(left), "right": jnp.asarray(right),
        "root": jnp.asarray(root), "x": jnp.asarray(x),
        "tree_ids": jnp.arange(N_TREES, dtype=jnp.int32),
    }


def item_fn(data):
    x = data["x"]

    def fn(t):
        def step(node, _):
            go_left = x[data["feat"][t, node]] < data["thr"][t, node]
            return jnp.where(go_left, data["left"][t, node], data["right"][t, node]), None

        node, _ = jax.lax.scan(step, data["root"][t], None, length=DEPTH)
        return data["leaf"][t, node]

    return fn


def items(data):
    return data["tree_ids"]


def cost(data):
    return dict(flops=DEPTH * 4.0, bytes=DEPTH * 128.0, chain=DEPTH, vector=True)


register(
    Benchmark(
        name="RF",
        domain="recommendation / ML serving",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
    )
)
