"""C. Obstacle Detection System (paper §VI.C).

3D KD-tree nearest-neighbour queries along a planned trajectory.
1000 obstacles in a 60³ m volume, 100 waypoints at 0.2 m resolution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite import common
from repro.bench_suite.common import Benchmark, register

N_OBST = 1000
N_WAY = 100
VISIT_BUDGET = 64


def build(seed=2):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 60, (N_OBST, 3)).astype(np.float32)
    kd = common.build_kdtree(pts)
    start = rng.uniform(10, 50, (3,))
    heading = rng.normal(size=3)
    heading /= np.linalg.norm(heading)
    way = (start[None] + 0.2 * np.arange(N_WAY)[:, None] * heading[None]).astype(
        np.float32
    )
    return {"kd": {k: jnp.asarray(v) for k, v in kd.items()}, "way": jnp.asarray(way)}


def _nn_query(kd, q):
    """Stack-budgeted branch-and-bound NN: returns min squared distance."""

    def step(carry, _):
        stack, sp, best = carry
        has = sp > 0
        node = jnp.where(has, stack[jnp.maximum(sp - 1, 0)], -1)
        sp = jnp.where(has, sp - 1, sp)
        nv = jnp.maximum(node, 0)
        pt = kd["point"][nv]
        ax = kd["axis"][nv]
        d2 = jnp.sum((pt - q) ** 2)
        best = jnp.where(jnp.logical_and(node >= 0, d2 < best), d2, best)
        diff = q[ax] - pt[ax]
        near = jnp.where(diff < 0, kd["left"][nv], kd["right"][nv])
        far = jnp.where(diff < 0, kd["right"][nv], kd["left"][nv])
        # push far child only if its half-space can beat `best`
        push_far = jnp.logical_and(
            jnp.logical_and(node >= 0, far >= 0), diff * diff < best
        )
        stack = jnp.where(push_far, stack.at[sp].set(far), stack)
        sp = sp + push_far.astype(jnp.int32)
        push_near = jnp.logical_and(node >= 0, near >= 0)
        stack = jnp.where(push_near, stack.at[sp].set(near), stack)
        sp = sp + push_near.astype(jnp.int32)
        return (stack, sp, best), None

    stack0 = jnp.zeros((48,), jnp.int32).at[0].set(kd["root"])
    (_, _, best), _ = jax.lax.scan(
        step, (stack0, jnp.int32(1), jnp.float32(1e9)), None, length=VISIT_BUDGET
    )
    return best


def item_fn(data):
    kd = data["kd"]

    def fn(waypoint):
        return jnp.sqrt(_nn_query(kd, waypoint))

    return fn


def items(data):
    return data["way"]


def cost(data):
    return dict(flops=VISIT_BUDGET * 12.0, bytes=VISIT_BUDGET * 64.0,
                chain=VISIT_BUDGET, vector=True)


register(
    Benchmark(
        name="LIDAR",
        domain="autonomous vehicles",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
    )
)
