"""A. Geo-Spatial Database System (paper §VI.A).

KD-tree range queries (iterative, stack-budgeted) → BST metadata lookup
per hit → per-object linked-list metric aggregation. 2048 objects in a
1000×1000 space, 15 concurrent 50×50 range queries per iteration, ≤32
hits per query.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite import common
from repro.bench_suite.common import Benchmark, register
from repro.core.deps import MemoryTrace

N_OBJECTS = 2048
N_QUERIES = 15
RANGE = 50.0
MAX_HITS = 32
VISIT_BUDGET = 96
BST_DEPTH = 12
LIST_HOPS = 12


def build(seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1000, (N_OBJECTS, 2)).astype(np.float32)
    kd = common.build_kdtree(pts)
    meta = common.build_bst(
        keys=np.arange(N_OBJECTS, dtype=np.int32),
        values=rng.integers(0, N_OBJECTS, N_OBJECTS).astype(np.int32),
    )
    lists = common.build_linked_lists(rng, N_OBJECTS, 4, LIST_HOPS - 2)
    lo = rng.uniform(0, 1000 - RANGE, (N_QUERIES, 2)).astype(np.float32)
    data = {
        "kd": {k: jnp.asarray(v) for k, v in kd.items()},
        "bst": {k: jnp.asarray(v) for k, v in meta.items()},
        "lists": {k: jnp.asarray(v) for k, v in lists.items()},
        "queries": jnp.concatenate([lo, lo + RANGE], axis=1),  # [Q, 4]
        "_np": {"kd": kd, "queries": np.concatenate([lo, lo + RANGE], 1)},
    }
    return data


def _range_query(kd, rect, budget=VISIT_BUDGET):
    """Stack-budgeted KD range search → (hit ids [MAX_HITS], n_hits)."""
    lo, hi = rect[:2], rect[2:]

    def step(carry, _):
        stack, sp, hits, nh = carry
        has = sp > 0
        node = jnp.where(has, stack[jnp.maximum(sp - 1, 0)], -1)
        sp = jnp.where(has, sp - 1, sp)
        nv = jnp.maximum(node, 0)
        pt = kd["point"][nv]
        ax = kd["axis"][nv]
        inside = jnp.logical_and(jnp.all(pt >= lo), jnp.all(pt <= hi))
        inside = jnp.logical_and(inside, node >= 0)
        hits = jnp.where(
            jnp.logical_and(inside, nh < MAX_HITS), hits.at[nh % MAX_HITS].set(nv), hits
        )
        nh = nh + inside.astype(jnp.int32)
        # push children whose half-space intersects the rect
        p_ax = pt[ax]
        go_l = jnp.logical_and(node >= 0, lo[ax] <= p_ax)
        go_r = jnp.logical_and(node >= 0, hi[ax] >= p_ax)
        l, r = kd["left"][nv], kd["right"][nv]
        push_l = jnp.logical_and(go_l, l >= 0)
        stack = jnp.where(push_l, stack.at[sp].set(l), stack)
        sp = sp + push_l.astype(jnp.int32)
        push_r = jnp.logical_and(go_r, r >= 0)
        stack = jnp.where(push_r, stack.at[sp].set(r), stack)
        sp = sp + push_r.astype(jnp.int32)
        return (stack, sp, hits, nh), None

    stack0 = jnp.zeros((64,), jnp.int32).at[0].set(kd["root"])
    hits0 = jnp.full((MAX_HITS,), -1, jnp.int32)
    (_, _, hits, nh), _ = jax.lax.scan(
        step, (stack0, jnp.int32(1), hits0, jnp.int32(0)), None, length=budget
    )
    return hits, jnp.minimum(nh, MAX_HITS)


def item_fn(data):
    kd, bst, lists = data["kd"], data["bst"], data["lists"]

    def fn(rect):
        hits, nh = _range_query(kd, rect)
        valid = hits >= 0
        obj = jnp.where(valid, kd["perm"][jnp.maximum(hits, 0)], 0)

        def per_hit(o, v):
            node = common.bst_lookup(bst, o, BST_DEPTH)
            mv = jnp.where(node >= 0, bst["value"][jnp.maximum(node, 0)], 0)
            s = common.list_sum(lists, lists["head"][jnp.minimum(mv, N_OBJECTS - 1)], LIST_HOPS)
            return jnp.where(v, s, 0.0)

        return jax.vmap(per_hit)(obj, valid).sum()

    return fn


def items(data):
    return data["queries"]


def cost(data):
    # per query: ~96 tree visits + ≤32·(12 BST + 12 list) dependent hops
    chain = VISIT_BUDGET + MAX_HITS * (BST_DEPTH + LIST_HOPS) // 2
    return dict(flops=400.0, bytes=chain * 64.0, chain=chain, vector=True)


def trace(data) -> MemoryTrace:
    """Numpy mirror of the KD walk: records visited node ids per query
    (the DynamoRIO load-trace analogue). Queries only read → no writes."""
    kd = data["_np"]["kd"]
    reads, writes = [], []
    for rect in data["_np"]["queries"]:
        lo, hi = rect[:2], rect[2:]
        stack, visited = [int(kd["root"])], []
        while stack and len(visited) < VISIT_BUDGET:
            n = stack.pop()
            visited.append(n)
            pt, ax = kd["point"][n], int(kd["axis"][n])
            if lo[ax] <= pt[ax] and kd["left"][n] >= 0:
                stack.append(int(kd["left"][n]))
            if hi[ax] >= pt[ax] and kd["right"][n] >= 0:
                stack.append(int(kd["right"][n]))
        reads.append(np.asarray(visited))
        writes.append(np.asarray([], np.int64))
    return MemoryTrace(reads=reads, writes=writes)


register(
    Benchmark(
        name="GeoSpatial",
        domain="geo-spatial database",
        build=build,
        items=items,
        item_fn=item_fn,
        cost=cost,
        trace=trace,
    )
)
