"""Fault-tolerant checkpointing: atomic, versioned, async, mesh-elastic.

Design points for 1000+-node runs:
  * atomic publish — write to ``step_N.tmp/`` then ``os.rename`` (a crashed
    writer never corrupts the restore point);
  * keep-k GC — bounded disk, oldest checkpoints pruned after publish;
  * async — the device→host transfer happens synchronously (cheap), the
    serialization happens on a background thread so the step loop isn't
    blocked (``wait()`` joins before the next save or at exit);
  * mesh-elastic restore — arrays are saved unsharded (host gathered) with
    their tree structure, so a checkpoint taken on a 512-chip mesh
    restores onto 256 chips (or 1 CPU) by re-sharding at load
    (``restore(..., shardings=...)``).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, *, meta: dict | None = None, blocking: bool = False):
        """Snapshot to host, then serialize (async unless blocking)."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        meta = dict(meta or {}, step=step, n_leaves=len(host_leaves))

        def work():
            tmp = self._step_dir(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(
                os.path.join(tmp, "arrays.npz"),
                **{f"leaf_{i}": a for i, a in enumerate(host_leaves)},
            )
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, tree_like: Any, step: int | None = None, *, shardings: Any = None):
        """Load into the structure of `tree_like`; optionally re-shard onto
        a (possibly different) mesh — the elastic-scaling path."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self._step_dir(step)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves, treedef = _flatten(tree_like)
        loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
        restored = []
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(loaded)
        )
        for ref, arr, shd in zip(leaves, loaded, shard_leaves):
            dtype = ref.dtype if hasattr(ref, "dtype") else arr.dtype
            a = arr.astype(dtype)
            restored.append(jax.device_put(a, shd) if shd is not None else jax.numpy.asarray(a))
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        return jax.tree_util.tree_unflatten(treedef, restored), meta
