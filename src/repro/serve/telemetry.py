"""Serving flight recorder: structured tracing + windowed metrics.

The paper's premise is that parallelization decisions must be driven by
*measured* signals (Aira profiles, collects dynamic dependencies, and
simulates before touching code).  This module is the serving-side
measurement substrate (DESIGN.md §8):

``Tracer``
    A bounded ring-buffer flight recorder of trace events with
    monotonic (``time.perf_counter``) microsecond timestamps.  Events
    are appended as plain tuples into a ``deque(maxlen=capacity)`` —
    recording never allocates device work, never syncs, and the oldest
    events fall off the back under sustained load.  ``export()`` writes
    Chrome/Perfetto trace-event JSON (load in ``ui.perfetto.dev`` or
    ``chrome://tracing``):

    * per-step **phase** events (admit / prefill-chunk / draft / verify
      / decode / sample) as complete ``"X"`` spans on the scheduler
      lane, nested under one span per scheduler step;
    * per-request **lifecycle** spans as async ``"b"``/``"n"``/``"e"``
      events keyed by request id (queued → admit → prefill-chunk* →
      first-token → preempt/resume → finish);
    * **adviser audit** events: ToolPipeline stages and advisor
      decisions (speculation K, attention backend) with their priced
      inputs, so an exported trace shows *why* each decision was made;
    * backend resolutions / mesh fallbacks as instant events.

``MetricsRegistry``
    Counters, gauges, and (unbounded) sample series shared with
    ``ServeStats``.  The scheduler calls ``tick()`` once per step when
    telemetry is enabled; each tick snapshots every counter/gauge into
    a bounded per-metric ring so ``window_summary(n)`` can answer "over
    the last *n* steps" — acceptance rate, queue depth, pool occupancy,
    step cost — exactly the signal vector the future online adviser
    (ROADMAP "online adaptive adviser") will consume.  ``snapshot()``
    returns a JSON-ready dict and ``prometheus_text()`` a
    Prometheus-style text exposition.

``Telemetry``
    Bundles a tracer (+ optional ``jax.profiler`` annotations) behind
    one ``enabled`` flag — the hard off-switch.  Disabled (the
    default), every instrumentation site in the serving hot path is a
    single attribute check and the code path is today's: no events, no
    ticks, no annotations.  A module-global default (``get_telemetry``)
    serves call sites with no engine handle (kernel backend registry,
    adviser tools); engines and schedulers accept an explicit
    ``telemetry=`` for isolation in tests.

This module is stdlib + numpy only (``jax.profiler`` imported lazily
inside ``annotate``) so ``core/`` and ``kernels/`` can use it without
an import cycle through the serving package.
"""
from __future__ import annotations

import json
import math
import time
from collections import deque
from contextlib import nullcontext
from typing import Any, Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Series",
    "MetricsRegistry",
    "Tracer",
    "Telemetry",
    "get_telemetry",
    "configure",
    "quantile",
    "validate_chrome_trace",
    "TID_STEP",
    "TID_REQUEST",
    "TID_ADVISER",
    "TID_BACKEND",
]

# One synthetic process, one thread lane per subsystem — fixed ids so
# Perfetto groups tracks deterministically across exports.
TRACE_PID = 1
TID_STEP = 0  # scheduler step + phase spans
TID_REQUEST = 1  # request lifecycle (async spans keyed by rid)
TID_ADVISER = 2  # ToolPipeline stages + advisor decisions
TID_BACKEND = 3  # kernel backend resolutions / mesh fallbacks

_THREAD_NAMES = {
    TID_STEP: "scheduler.step",
    TID_REQUEST: "requests",
    TID_ADVISER: "adviser",
    TID_BACKEND: "backend",
}

_NULL_CM = nullcontext()


def quantile(vals, p: float) -> float:
    """Linear-interpolated percentile (``p`` in [0, 100]) matching
    ``numpy.percentile``'s default method: rank ``(n-1)·p/100`` is
    interpolated between the two bracketing order statistics, so p99
    over a short series does NOT collapse to the max the way a
    nearest-rank estimator does.  Pure python on a sorted copy — used
    by both ``ServeStats.percentile`` and the registry windows."""
    vals = sorted(vals)
    n = len(vals)
    if n == 0:
        return 0.0
    if n == 1:
        return float(vals[0])
    rank = (n - 1) * (float(p) / 100.0)
    lo = int(rank)
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


class Counter:
    """Monotonic (but resettable) cumulative value with a per-tick ring."""

    __slots__ = ("name", "value", "ring")

    def __init__(self, name: str, window: int):
        self.name = name
        self.value = 0.0
        self.ring: deque = deque(maxlen=window)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0.0
        self.ring.clear()


class Gauge:
    """Last-set value with a per-tick ring of samples."""

    __slots__ = ("name", "value", "ring")

    def __init__(self, name: str, window: int):
        self.name = name
        self.value: float | None = None
        self.ring: deque = deque(maxlen=window)

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = None
        self.ring.clear()


class Series(list):
    """Unbounded sample list (a real ``list`` so existing
    ``stats.step_ms.append(...)`` call sites keep working verbatim)
    with rolling-quantile helpers over its tail."""

    def __init__(self, name: str, iterable: Iterable[float] = ()):  # noqa: D107
        super().__init__(iterable)
        self.name = name

    def quantile(self, p: float, window: int | None = None) -> float:
        tail = self if window is None else self[-window:]
        return quantile(tail, p)


class MetricsRegistry:
    """Name → metric registry with per-step windows.

    Counters and gauges are cumulative/instantaneous; ``tick()`` (one
    call per scheduler step when telemetry is on) snapshots each into a
    bounded ring so windowed deltas/means need no timestamps.  Series
    are unbounded sample lists (ServeStats latency series) with
    rolling-quantile reads.  Metric objects are stable across
    ``reset()`` so hot-path call sites can cache them."""

    def __init__(self, window: int = 512):
        self.window = int(window)
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._series: dict[str, Series] = {}
        self._ticks = 0

    # -- registration ------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, self.window)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, self.window)
        return g

    def series(self, name: str) -> Series:
        s = self._series.get(name)
        if s is None:
            s = self._series[name] = Series(name)
        return s

    # -- windows -----------------------------------------------------
    @property
    def ticks(self) -> int:
        return self._ticks

    def tick(self) -> None:
        """Snapshot every counter/gauge into its ring (one scheduler
        step boundary).  O(#metrics) python appends, no device work."""
        self._ticks += 1
        for c in self._counters.values():
            c.ring.append(c.value)
        for g in self._gauges.values():
            if g.value is not None:
                g.ring.append(g.value)

    def window_delta(self, name: str, n: int) -> float:
        """Increase of counter ``name`` over the last ``n`` ticks."""
        ring = self._counters[name].ring if name in self._counters else None
        if not ring:
            return 0.0
        base = ring[-n - 1] if len(ring) > n else 0.0
        return float(ring[-1] - base)

    def window_mean(self, name: str, n: int) -> float:
        """Mean of gauge ``name`` over its last ``n`` tick samples."""
        ring = self._gauges[name].ring if name in self._gauges else None
        if not ring:
            return 0.0
        tail = list(ring)[-n:]
        return float(sum(tail) / len(tail))

    def series_quantile(self, name: str, p: float, n: int | None = None) -> float:
        s = self._series.get(name)
        return s.quantile(p, n) if s else 0.0

    def window_summary(self, n: int = 32) -> dict[str, Any]:
        """The online-adviser signal vector over the last ``n`` steps
        (``serve.controller.OnlineAdviser`` consumes this every
        decision interval): windowed speculation acceptance, the
        draft/verify cost split, queue depth, pool occupancy/pressure,
        and step cost, plus the admission/preemption/eviction rates
        that price a re-decision.  Purely a read — token streams are
        unaffected.

        Cold-start contract: every value is a well-defined finite float
        (or int) even with zero ticks, a window shorter than ``n``, or
        all-zero denominators — the controller reads this vector on
        step 1, before any speculation/prefill has happened, and 0.0
        means "no signal yet", never NaN/None."""
        proposed = self.window_delta("serve.spec_proposed", n)
        accepted = self.window_delta("serve.spec_accepted", n)
        prompt = self.window_delta("serve.prompt_tokens", n)
        hits = self.window_delta("serve.prefix_hit_tokens", n)
        eff = max(1, min(n, self._ticks))
        summary = {
            "window": min(n, self._ticks),
            "ticks": self._ticks,
            "acceptance_rate": accepted / proposed if proposed else 0.0,
            "proposed": proposed,
            "accepted": accepted,
            "spec_steps": self.window_delta("serve.spec_steps", n),
            "queue_depth": self.window_mean("sched.queue_depth", n),
            "active": self.window_mean("sched.active", n),
            "pool_occupancy": self.window_mean("pool.occupancy", n),
            "pool_free_blocks": self.window_mean("pool.free_blocks", n),
            "step_cost_ms": self.series_quantile("serve.step_ms", 50.0, n),
            "p99_step_ms": self.series_quantile("serve.step_ms", 99.0, n),
            "p50_draft_ms": self.series_quantile("serve.draft_ms", 50.0, n),
            "p50_verify_ms": self.series_quantile("serve.verify_ms", 50.0, n),
            "admitted": self.window_delta("sched.admitted", n),
            "preemptions": self.window_delta("serve.preemptions", n),
            "rejected": self.window_delta("serve.rejected_submissions", n),
            "prefix_hit_rate": hits / prompt if prompt else 0.0,
            "chunk_utilization": self.series_quantile("sched.chunk_util", 50.0, n),
            "alloc_rate": self.window_delta("pool.alloc", n) / eff,
            "evict_rate": self.window_delta("pool.evict", n) / eff,
            "park_rate": self.window_delta("pool.park", n) / eff,
            "retraces": self.window_delta("engine.retraces", n),
        }
        return {
            k: (v if isinstance(v, int) else (float(v) if math.isfinite(v) else 0.0))
            for k, v in summary.items()
        }

    # -- exposition --------------------------------------------------
    def snapshot(self) -> dict[str, Any]:
        """JSON-ready dump of every metric's current state."""
        return {
            "ticks": self._ticks,
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "series": {
                n: {
                    "count": len(s),
                    "p50": s.quantile(50.0),
                    "p99": s.quantile(99.0),
                }
                for n, s in sorted(self._series.items())
            },
        }

    def prometheus_text(self) -> str:
        """Prometheus text exposition (``.`` → ``_`` in names; series
        exported as summary quantiles + count)."""
        lines: list[str] = []
        for name, c in sorted(self._counters.items()):
            pname = name.replace(".", "_")
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {c.value:g}")
        for name, g in sorted(self._gauges.items()):
            if g.value is None:
                continue
            pname = name.replace(".", "_")
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {g.value:g}")
        for name, s in sorted(self._series.items()):
            pname = name.replace(".", "_")
            lines.append(f"# TYPE {pname} summary")
            lines.append(f'{pname}{{quantile="0.5"}} {s.quantile(50.0):g}')
            lines.append(f'{pname}{{quantile="0.99"}} {s.quantile(99.0):g}')
            lines.append(f"{pname}_count {len(s)}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Zero counters, clear gauges/series/rings IN PLACE — metric
        objects cached by hot-path call sites stay valid."""
        self._ticks = 0
        for c in self._counters.values():
            c.reset()
        for g in self._gauges.values():
            g.reset()
        for s in self._series.values():
            s.clear()


class Tracer:
    """Bounded ring-buffer flight recorder of Chrome trace events.

    Events are stored as tuples ``(ph, name, cat, ts_us, dur_us, tid,
    id, args)`` in a ``deque(maxlen=capacity)`` — appending is O(1) and
    the buffer can never exceed its bound (oldest events are dropped
    first, like a flight recorder).  Timestamps are microseconds from
    the tracer's own ``perf_counter`` epoch."""

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._t0 = time.perf_counter()

    # -- recording ---------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def to_us(self, t: float) -> float:
        """Convert a raw ``time.perf_counter()`` reading to trace µs."""
        return (t - self._t0) * 1e6

    def complete(self, name, cat, ts_us, dur_us, tid=TID_STEP, args=None) -> None:
        self._events.append(("X", name, cat, ts_us, max(0.0, dur_us), tid, None, args))

    def instant(self, name, cat, tid=TID_STEP, args=None, ts_us=None) -> None:
        ts = self.now_us() if ts_us is None else ts_us
        self._events.append(("i", name, cat, ts, None, tid, None, args))

    def async_begin(self, name, id_, cat, args=None, ts_us=None) -> None:
        ts = self.now_us() if ts_us is None else ts_us
        self._events.append(("b", name, cat, ts, None, TID_REQUEST, id_, args))

    def async_instant(self, name, id_, cat, args=None, ts_us=None) -> None:
        ts = self.now_us() if ts_us is None else ts_us
        self._events.append(("n", name, cat, ts, None, TID_REQUEST, id_, args))

    def async_end(self, name, id_, cat, args=None, ts_us=None) -> None:
        ts = self.now_us() if ts_us is None else ts_us
        self._events.append(("e", name, cat, ts, None, TID_REQUEST, id_, args))

    # -- reading -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    @property
    def events(self) -> list[tuple]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    def chrome_events(self) -> list[dict[str, Any]]:
        """Render the ring to Chrome trace-event dicts, prefixed by
        process/thread metadata so Perfetto labels the tracks."""
        out: list[dict[str, Any]] = [
            {
                "name": "process_name",
                "ph": "M",
                "pid": TRACE_PID,
                "tid": 0,
                "args": {"name": "repro.serve"},
            }
        ]
        for tid, tname in _THREAD_NAMES.items():
            out.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": TRACE_PID,
                    "tid": tid,
                    "args": {"name": tname},
                }
            )
        for ph, name, cat, ts, dur, tid, id_, args in self._events:
            ev: dict[str, Any] = {
                "name": name,
                "cat": cat,
                "ph": ph,
                "ts": round(ts, 3),
                "pid": TRACE_PID,
                "tid": tid,
            }
            if ph == "X":
                ev["dur"] = round(dur, 3)
            if ph == "i":
                ev["s"] = "t"
            if id_ is not None:
                ev["id"] = id_
            if args:
                ev["args"] = args
            out.append(ev)
        return out

    def to_chrome_trace(self) -> dict[str, Any]:
        return {"traceEvents": self.chrome_events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> dict[str, Any]:
        """Write Perfetto-loadable JSON to ``path``; returns the dict."""
        trace = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f, default=str)
        return trace


class Telemetry:
    """Tracer (+ optional XLA annotations) behind one hard off-switch.

    ``enabled=False`` (the default, and the module global's state) is
    the off-switch the tentpole requires: every instrumentation site
    guards on ``tel.enabled`` (or on a cached metric handle that is
    ``None`` when disabled), so the serving hot path is unchanged.
    ``xla_annotations=True`` additionally wraps device-launching phases
    in ``jax.profiler.TraceAnnotation`` so XLA device profiles carry
    our phase names — off by default even when tracing, since it adds
    a TraceMe per launch."""

    def __init__(
        self,
        enabled: bool = False,
        capacity: int = 65536,
        xla_annotations: bool = False,
    ):
        self.enabled = bool(enabled)
        self.xla_annotations = bool(xla_annotations)
        self.tracer = Tracer(capacity)
        self._annotation_cls = None

    def annotate(self, name: str):
        """Context manager for a device-launching phase: a
        ``jax.profiler.TraceAnnotation`` when enabled AND
        ``xla_annotations`` is set, else a shared no-op context."""
        if not (self.enabled and self.xla_annotations):
            return _NULL_CM
        if self._annotation_cls is None:
            from jax.profiler import TraceAnnotation  # lazy: keep module jax-free

            self._annotation_cls = TraceAnnotation
        return self._annotation_cls(name)

    def count(self, name: str, n: float = 1.0, registry: MetricsRegistry | None = None) -> None:
        """Convenience for rare, engine-less call sites (backend
        registry, mesh fallbacks): bump a counter on ``registry`` (or
        the global one) iff enabled."""
        if not self.enabled:
            return
        (registry or _GLOBAL_REGISTRY).counter(name).inc(n)


# Module-global default: disabled. `configure()` flips it for CLI runs
# (serving_load --trace, serve_decode --trace); tests build their own
# `Telemetry()` instances and pass them to the engine for isolation.
GLOBAL = Telemetry(enabled=False)
_GLOBAL_REGISTRY = MetricsRegistry()


def get_telemetry() -> Telemetry:
    return GLOBAL


def global_registry() -> MetricsRegistry:
    """Registry backing engine-less counters recorded via
    ``Telemetry.count`` (backend resolutions, mesh fallbacks)."""
    return _GLOBAL_REGISTRY


def configure(
    enabled: bool = True,
    capacity: int = 65536,
    xla_annotations: bool = False,
) -> Telemetry:
    """(Re)arm the module-global telemetry — fresh tracer, same object
    identity so call sites that grabbed ``get_telemetry()`` see it."""
    GLOBAL.enabled = bool(enabled)
    GLOBAL.xla_annotations = bool(xla_annotations)
    GLOBAL.tracer = Tracer(capacity)
    return GLOBAL


_VALID_PH = {"X", "B", "E", "i", "I", "b", "n", "e", "M", "C", "s", "t", "f"}


def validate_chrome_trace(trace: Any) -> dict[str, int]:
    """Validate Chrome trace-event JSON structure; raises ``ValueError``
    on the first violation, returns event counts on success.

    Checks the schema chrome://tracing and Perfetto actually require:
    a ``traceEvents`` list (or bare list) of dicts, each with a string
    ``name``, known ``ph``, numeric ``ts`` (metadata exempt) and
    ``pid``/``tid``; ``X`` events carry a non-negative ``dur``; async
    ``b``/``n``/``e`` events carry an ``id`` and every ``e`` closes a
    previously opened ``b`` of the same (cat, id)."""
    events = trace.get("traceEvents") if isinstance(trace, dict) else trace
    if not isinstance(events, list):
        raise ValueError("trace must be a list or have a 'traceEvents' list")
    counts = {"events": 0, "spans": 0, "async_spans": 0, "instants": 0}
    open_async: dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not a dict")
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            raise ValueError(f"event {i}: bad ph {ph!r}")
        if not isinstance(ev.get("name"), str):
            raise ValueError(f"event {i}: missing name")
        if ph != "M":
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"event {i}: missing ts")
            if ev["ts"] < 0:
                raise ValueError(f"event {i}: negative ts")
        if "pid" not in ev or "tid" not in ev:
            raise ValueError(f"event {i}: missing pid/tid")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: X without non-negative dur")
            counts["spans"] += 1
        if ph in ("i", "I"):
            counts["instants"] += 1
        if ph in ("b", "n", "e"):
            if "id" not in ev:
                raise ValueError(f"event {i}: async {ph!r} without id")
            key = (ev.get("cat"), ev["id"])
            if ph == "b":
                open_async[key] = open_async.get(key, 0) + 1
                counts["async_spans"] += 1
            elif ph == "e":
                if open_async.get(key, 0) < 1:
                    # the ring may have evicted the matching "b"; only a
                    # strict violation when the buffer never wrapped
                    raise ValueError(f"event {i}: async end without begin {key}")
                open_async[key] -= 1
        counts["events"] += 1
    return counts
