"""Serve-level differential comparators (DESIGN.md §5).

Two contracts, two lanes:

* **Bitwise** — head-only ("model") meshes move parallel work between
  ranks without changing any reduction order, so their token streams —
  and the logits behind them — must equal the single-device paged path
  exactly. ``assert_streams_equal`` is that lane.

* **Tolerance** — kv-sequence-split ("seq", and 2D ("model","seq"))
  meshes recombine each row's softmax from per-rank flash partials
  through ``distributed_softmax``; the combine is *exact* in real
  arithmetic but associates the float reductions differently, so logits
  agree only to rounding. The observable contract is therefore argmax
  token identity (greedy streams are argmax decisions) plus a
  max-abs-logit bound: ``assert_streams_equal`` still applies to the
  emitted tokens, and ``assert_logits_close`` pins the one-step logit
  gap and NaN-freedom (the empty-shard guard's hot-path obligation).

Streams are matched by admission order, not rid: rids are globally
auto-assigned, so two ``serve()`` calls over equal workloads hand out
different ids for corresponding requests.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "match_streams",
    "assert_streams_equal",
    "assert_logits_close",
]


def match_streams(base: dict, other: dict):
    """Pair two ``serve()`` result dicts (rid → token array) by
    admission order. Returns a list of ``(tokens_base, tokens_other)``
    numpy pairs; raises if the workloads differ in size."""
    if len(base) != len(other):
        raise AssertionError(
            f"stream count mismatch: {len(base)} vs {len(other)}"
        )
    pairs = []
    for (_, va), (_, vb) in zip(sorted(base.items()), sorted(other.items())):
        pairs.append((np.asarray(va), np.asarray(vb)))
    return pairs


def assert_streams_equal(base: dict, other: dict, *, label: str = ""):
    """Every matched stream's tokens are identical. This is the full
    contract for head-only meshes (bitwise lane) and the token half of
    the tolerance lane: greedy tokens are argmax decisions, so argmax
    token identity *is* stream equality."""
    for i, (va, vb) in enumerate(match_streams(base, other)):
        np.testing.assert_array_equal(
            va, vb, err_msg=f"{label} stream #{i} (admission order) diverged"
        )


def assert_logits_close(base, other, *, atol: float, label: str = ""):
    """One-step logit comparator for the tolerance lane: ``other`` must
    be NaN-free (the empty-shard guard's obligation once the combine is
    on the hot path), agree with ``base`` on every row's argmax, and
    stay within ``atol`` max-abs difference."""
    a = np.asarray(base, np.float64)
    b = np.asarray(other, np.float64)
    if a.shape != b.shape:
        raise AssertionError(f"{label} logits shape {a.shape} vs {b.shape}")
    if np.isnan(b).any():
        raise AssertionError(f"{label} sharded logits contain NaN")
    am_a, am_b = a.argmax(-1), b.argmax(-1)
    if not (am_a == am_b).all():
        bad = int((am_a != am_b).sum())
        raise AssertionError(
            f"{label} argmax disagrees on {bad}/{am_a.size} rows"
        )
    gap = float(np.abs(a - b).max())
    if gap > atol:
        raise AssertionError(f"{label} max|Δlogit| {gap:.3e} > atol {atol:.1e}")
    return gap
