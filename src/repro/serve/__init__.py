from repro.serve.engine import ServingEngine  # noqa: F401
from repro.serve.kv_cache import SlotKVCache  # noqa: F401
from repro.serve.load import make_requests  # noqa: F401
from repro.serve.request import Request, ServeStats  # noqa: F401
from repro.serve.scheduler import Scheduler  # noqa: F401
