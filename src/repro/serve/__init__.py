from repro.serve.block import BlockAllocator, PrefixCache  # noqa: F401
from repro.serve.controller import (  # noqa: F401
    Decision,
    OnlineAdviser,
    PinnedController,
)
from repro.serve.differential import (  # noqa: F401
    assert_logits_close,
    assert_streams_equal,
    match_streams,
)
from repro.serve.engine import ServingEngine  # noqa: F401
from repro.serve.kv_cache import PagedKVCache, SlotKVCache  # noqa: F401
from repro.serve.load import (  # noqa: F401
    make_drift_requests,
    make_requests,
    make_shared_prefix_requests,
    make_slo_requests,
)
from repro.serve.request import Request, ServeStats  # noqa: F401
from repro.serve.scheduler import Scheduler  # noqa: F401
from repro.serve.speculative import (  # noqa: F401
    DraftSource,
    ModelDraftSource,
    NGramDraftSource,
    SpecConfig,
    advise_depth,
)
from repro.serve.telemetry import (  # noqa: F401
    MetricsRegistry,
    Telemetry,
    Tracer,
    configure,
    get_telemetry,
    validate_chrome_trace,
)
