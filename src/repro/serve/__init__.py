from repro.serve.engine import ServingEngine  # noqa: F401
