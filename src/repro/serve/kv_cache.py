"""KV-cache pools for continuous batching: slotted and block-paged.

``SlotKVCache`` owns a fixed pool of ``max_batch`` decode-cache slots
(one ``Model.init_cache(max_batch, max_seq)`` allocation, made once).
Slots are allocated when a request is admitted and freed when it
finishes or hits EOS; the decode step always runs over the *whole*
pool, so its jit shape never changes — liveness is the ``live_mask``
the masked plan execution consumes (DESIGN.md §3).

``PagedKVCache`` replaces the slot's monolithic ``max_seq`` reservation
with block-granular memory: a ``BlockAllocator`` pool of fixed-size
blocks, a per-row *block table* mapping logical token positions to
physical blocks, and (for dense/audio families) a trie-based
``PrefixCache`` that lets requests whose prompts share a token prefix
alias the same immutable blocks instead of recomputing them. Admission
charges blocks (worst case reserved, physical blocks allocated lazily
as decode crosses block boundaries), so footprint scales with actual
lengths, not ``max_seq`` (DESIGN.md §3 "Paged cache & prefix reuse").

All per-family cache logic rides on ``Model`` metadata
(``cache_batch_axes`` / ``read_cache_slot`` / ``write_cache_slot`` for
slots, ``init_paged_cache`` / ``paged_view`` / ``decode_step_paged``
for pages), so this module never inspects cache leaves itself.
"""
from __future__ import annotations

import bisect
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import PREFIX_FAMILIES
from repro.serve.block import BlockAllocator, PrefixCache


def local_table_view(tables, nb_loc: int, rank):
    """Per-rank view of the replicated block tables under the kv-sequence
    split (jit/shard_map-traceable; called from ``attention_block``).

    ``PagedKVCache`` lays pool slots out in ``seq_shards`` contiguous
    shards of ``nb_loc`` slots, each ending in one reserved scratch slot
    (never the image of any allocator/null id). Rank ``r`` owns global
    slots ``[r·nb_loc, (r+1)·nb_loc)``; its local view maps owned
    entries to their in-shard offset and redirects every unowned entry
    to the rank's scratch slot ``nb_loc - 1`` — a safe DMA source (its
    positions are skipped via ``owned``) and a safe write target (the
    owner rank writes the real data; everyone else clobbers scratch).
    Returns ``(local_tables [B, MB], owned [B, MB] bool)``."""
    owned = (tables // nb_loc) == rank
    local = jnp.where(owned, tables % nb_loc, nb_loc - 1)
    return local.astype(tables.dtype), owned


class SlotKVCache:
    """Fixed pool of cache slots: allocate on admit, free on finish."""

    def __init__(self, model, max_batch: int, max_seq: int, dtype=None):
        self.model = model
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cache = model.init_cache(self.max_batch, self.max_seq, dtype=dtype)
        self._free: list[int] = list(range(self.max_batch))  # ascending
        self._owner: list[Optional[int]] = [None] * self.max_batch  # slot → rid

    # ------------------------------------------------------------------
    # occupancy
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.max_batch - len(self._free)

    @property
    def occupancy(self) -> float:
        """Pool-pressure gauge in [0, 1]: fraction of slots live (the
        slotted layout's only capacity axis)."""
        return self.n_live / self.max_batch

    def owner(self, slot: int) -> Optional[int]:
        return self._owner[slot]

    def live_mask(self):
        """[max_batch] bool — which slots hold live requests."""
        import numpy as np

        return np.array([o is not None for o in self._owner])

    def live_slots(self) -> list[int]:
        return [i for i, o in enumerate(self._owner) if o is not None]

    # ------------------------------------------------------------------
    # slot lifecycle
    def alloc(self, rid: int) -> int:
        """Claim the lowest free slot for request ``rid``."""
        if not self._free:
            raise RuntimeError("no free cache slot (pool exhausted)")
        slot = self._free.pop(0)
        self._owner[slot] = rid
        return slot

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.max_batch:
            raise IndexError(f"slot {slot} out of range")
        if self._owner[slot] is None:
            raise RuntimeError(f"double free of slot {slot}")
        self._owner[slot] = None
        bisect.insort(self._free, slot)

    # ------------------------------------------------------------------
    # cache I/O (family-agnostic, via the model's batch-axis metadata)
    def write(self, slot: int, slot_cache) -> None:
        """Install a batch=1 cache (a request's prefill) into ``slot``."""
        if self._owner[slot] is None:
            raise RuntimeError(f"write into free slot {slot}")
        self.cache = self.model.write_cache_slot(self.cache, slot_cache, slot)

    def read(self, slot: int):
        """Slot ``slot`` as a batch=1 cache."""
        return self.model.read_cache_slot(self.cache, slot)

    # ------------------------------------------------------------------
    # speculative rollback
    def truncate_row(self, slot: int, n_rejected: int) -> None:
        """Rewind ``n_rejected`` rejected speculative entries off slot
        ``slot``: the committed length drops; the stale KV rows past it
        are masked off by ``len`` and overwritten by later writes, so
        the values themselves need no cleanup (DESIGN.md §3.2)."""
        if self._owner[slot] is None:
            raise RuntimeError(f"truncate of free slot {slot}")
        new_len = jnp.maximum(self.cache["len"][slot] - int(n_rejected), 0)
        self.cache["len"] = self.cache["len"].at[slot].set(new_len)

    def truncate_rows(self, n_rejected) -> None:
        """Vectorized rewind: ``n_rejected`` [max_batch] entries come
        off every row's length in one update (dead and just-retired
        rows pass the full verify width, so their lengths return to the
        pre-verify value and never drift)."""
        vec = jnp.asarray(np.asarray(n_rejected, np.int32))
        self.cache["len"] = jnp.maximum(self.cache["len"] - vec, 0)

    # ------------------------------------------------------------------
    # preemption (uniform scheduler call; the slotted layout has no
    # prefix trie, so eviction just frees — resume is a cold re-prefill)
    def preempt_row(self, slot: int, tokens=None) -> None:
        del tokens  # no trie to register committed work into
        self.free(slot)

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Free slots and live slots partition the pool; the free list is
        sorted and duplicate-free (used by the property tests)."""
        live = {i for i, o in enumerate(self._owner) if o is not None}
        free = set(self._free)
        assert len(self._free) == len(free), "duplicate in free list"
        assert not (free & live), "slot both free and live"
        assert free | live == set(range(self.max_batch)), "slot leaked"
        assert self._free == sorted(self._free), "free list unsorted"


class PagedKVCache:
    """Block-paged KV cache with shared-prefix reuse.

    ``max_batch`` decode *rows* (the fixed jit batch, like slots) map
    through per-row block tables into a pool of ``num_blocks`` physical
    blocks of ``block_size`` tokens. Admission charges the worst-case
    block budget (so lazy tail-block allocation can never fail
    mid-decode), but physical blocks are claimed only as the request
    actually reaches them — footprint scales with real lengths, and
    prefix-shared blocks are charged once.
    """

    def __init__(
        self,
        model,
        max_batch: int,
        max_seq: int,
        *,
        block_size: int = 8,
        num_blocks: Optional[int] = None,
        prefix_cache: bool = True,
        dtype=None,
        mesh=None,
        metrics=None,
    ):
        self.model = model
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        self.block_size = int(block_size)
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_seq % self.block_size:
            raise ValueError(
                f"max_seq={max_seq} must be a multiple of block_size={block_size}"
            )
        self.blocks_per_row = self.max_seq // self.block_size
        if num_blocks is None:
            num_blocks = self.max_batch * self.blocks_per_row
        self.num_blocks = int(num_blocks)
        # Physical slot layout. The allocator hands out ids [0, num_blocks)
        # plus the null id num_blocks; ``_slot`` maps ids onto pool slots.
        # Single-shard (no seq axis): identity, one spare slot past the
        # allocator's range — unowned block-table entries point here, so
        # dead rows' decode writes land in scratch. kv-sequence split
        # (mesh with a "seq" axis of size sp > 1): the pool's block dim is
        # partitioned over sp contiguous shards, and ids are laid out so
        # every shard ends in one reserved scratch slot that is never the
        # image of any id — per-rank table views (``local_table_view``)
        # redirect unowned entries there, so foreign-rank writes always
        # land in rank-local scratch (DESIGN.md §5).
        self.mesh = mesh
        sp = int(mesh.shape.get("seq", 1)) if mesh is not None else 1
        self.seq_shards = sp
        ids = self.num_blocks + 1  # allocator range + the null id
        if sp > 1:
            d = math.ceil(ids / sp)  # data slots per shard
            arange = np.arange(ids, dtype=np.int32)
            self._slot = ((arange // d) * (d + 1) + arange % d).astype(np.int32)
            self.total_blocks = sp * (d + 1)
        else:
            self._slot = np.arange(ids, dtype=np.int32)
            self.total_blocks = ids
        self.null_block = int(self._slot[self.num_blocks])
        self.pool = model.init_paged_cache(
            self.total_blocks, self.block_size, dtype=dtype
        )
        # serving mesh (DESIGN.md §5): allocate the pool sharded over the
        # mesh once — head-partitioned on the kv-head dim ("model") and/or
        # block-partitioned on the block dim ("seq"); the sharded step's
        # donation keeps every subsequent new_pool on the same
        # NamedSharding, so KV bytes never migrate between ranks.
        # Tables/lengths stay host-side numpy (they are data, replicated
        # on upload by the step's in_specs).
        if mesh is not None:
            from jax.sharding import NamedSharding

            tp = int(mesh.shape.get("model", 1))
            specs = model.paged_pool_specs(
                "model" if tp > 1 else None, "seq" if sp > 1 else None
            )
            self.pool = {
                name: jax.device_put(leaf, NamedSharding(mesh, specs[name]))
                for name, leaf in self.pool.items()
            }
        cfg = model.cfg
        # PREFIX_FAMILIES lives next to the model's prefill_with_prefix,
        # which enforces the same exclusions — the two layers can't
        # drift. int8-KV participates: gather_prefix dequantizes hit
        # blocks for the suffix path, and the suffix prefill requantizes
        # (idempotently) on the way back.
        self.prefix = (
            PrefixCache(self.block_size)
            if prefix_cache and cfg.family in PREFIX_FAMILIES
            else None
        )
        # optional telemetry.MetricsRegistry (DESIGN.md §8): the
        # allocator records alloc/share/park/evict rates; this layer
        # adds trie lookup/hit counters. None (telemetry off) keeps the
        # uninstrumented path.
        self._m_lookups = metrics.counter("prefix.lookups") if metrics else None
        self._m_hit_blocks = metrics.counter("prefix.hit_blocks") if metrics else None
        self.allocator = BlockAllocator(
            self.num_blocks,
            on_evict=self.prefix.drop_block if self.prefix is not None else None,
            is_leaf=self.prefix.is_leaf if self.prefix is not None else None,
            metrics=metrics,
        )
        self.block_tables = np.full(
            (self.max_batch, self.blocks_per_row), self.null_block, np.int32
        )
        self.cache_len = np.zeros((self.max_batch,), np.int32)
        # kernel_inputs() device views, invalidated by version counter:
        # tables mutate only on admission / tail claim / truncate / free,
        # so steady-state decode re-uploads ONLY the lengths vector
        self._tables_version = 0
        self._dev_tables = None
        self._dev_tables_version = -1
        self._len_version = 0
        self._dev_len = None
        self._dev_len_version = -1
        self._row_free: list[int] = list(range(self.max_batch))  # ascending
        self._row_owner: list[Optional[int]] = [None] * self.max_batch
        self._row_blocks: list[list[int]] = [[] for _ in range(self.max_batch)]
        self._row_outstanding = [0] * self.max_batch  # reserved, unallocated
        self._outstanding_total = 0

    # ------------------------------------------------------------------
    # occupancy (row API mirrors SlotKVCache so the scheduler is shared)
    @property
    def n_free(self) -> int:
        return len(self._row_free)

    @property
    def n_live(self) -> int:
        return self.max_batch - len(self._row_free)

    @property
    def n_free_blocks(self) -> int:
        """Blocks an arriving request could claim right now: free +
        LRU-evictable cached, minus live rows' outstanding reservations."""
        return self.allocator.n_available - self._outstanding_total

    @property
    def occupancy(self) -> float:
        """Pool-pressure gauge in [0, 1]: fraction of physical blocks
        holding data (live + parked; outstanding reservations excluded
        — they are a promise, not bytes)."""
        return 1.0 - self.allocator.n_free / self.num_blocks

    def owner(self, row: int) -> Optional[int]:
        return self._row_owner[row]

    def live_mask(self):
        return np.array([o is not None for o in self._row_owner])

    def live_rows(self) -> list[int]:
        return [i for i, o in enumerate(self._row_owner) if o is not None]

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    # ------------------------------------------------------------------
    # admission
    def lookup(self, tokens) -> list[int]:
        """Prefix-cache hit: block ids covering the longest cached full-
        block prefix of ``tokens`` (empty when prefix reuse is off)."""
        if self.prefix is None:
            return []
        hits = self.prefix.match(tokens)
        if self._m_lookups is not None:
            self._m_lookups.inc()
            self._m_hit_blocks.inc(len(hits))
        return hits

    def try_admit(
        self,
        rid: int,
        tokens,
        budget: int,
        n_tokens: Optional[int] = None,
        register: bool = True,
    ):
        """Admit ``rid`` into a free row if the block budget fits:
        returns (row, hit_ids) or None. Shared prefix blocks alias
        (refcount++); fresh prompt blocks are allocated now; the decode
        tail is only *reserved* (allocated lazily by ``ensure_tail``).
        ``n_tokens`` overrides the cache-row count when the prefill
        occupies more rows than ``tokens`` (VLM patch embeddings).
        ``register=False`` defers trie registration (chunked prefill:
        the prompt blocks hold no KV yet at admission — the scheduler
        calls ``register_prompt`` once the last chunk has committed, so
        a concurrent admission can never alias half-written blocks)."""
        if not self._row_free:
            return None
        S = len(tokens) if n_tokens is None else int(n_tokens)
        hit_ids = self.lookup(tokens)
        n_total = self.blocks_for(S + budget)
        n_prompt = self.blocks_for(S)
        n_parked_hits = sum(self.allocator.is_parked(b) for b in hit_ids)
        # after reactivating parked hits, enough must remain for this
        # request's fresh blocks AND every live row's reservations
        need = (n_total - len(hit_ids)) + n_parked_hits
        if self.allocator.n_available < self._outstanding_total + need:
            return None
        for b in hit_ids:  # reactivate/alias FIRST so eviction can't take them
            self.allocator.share(b)
        blocks = list(hit_ids)
        for _ in range(n_prompt - len(hit_ids)):
            blocks.append(self.allocator.alloc())
        row = self._row_free.pop(0)
        self._row_owner[row] = rid
        self._row_blocks[row] = blocks
        self._row_outstanding[row] = n_total - n_prompt
        self._outstanding_total += self._row_outstanding[row]
        self.block_tables[row, : len(blocks)] = self._slot[blocks]
        self.cache_len[row] = S
        self._tables_version += 1
        self._len_version += 1
        if register and self.prefix is not None and len(tokens) == S:
            # register the prompt's immutable full blocks (decode never
            # writes before position S, so blocks < S // bs stay frozen)
            self.prefix.insert(tokens, blocks[: S // self.block_size])
        return row, hit_ids

    def register_prompt(self, row: int, tokens) -> None:
        """Register a live row's now-written prompt blocks in the trie
        (the deferred half of ``try_admit(register=False)``). ``tokens``
        must be the prompt whose KV the row's leading blocks hold."""
        if self._row_owner[row] is None:
            raise RuntimeError(f"register_prompt on free row {row}")
        if self.prefix is None:
            return
        n_full = min(len(tokens) // self.block_size, len(self._row_blocks[row]))
        if n_full:
            self.prefix.insert(tuple(tokens)[: n_full * self.block_size],
                               self._row_blocks[row][:n_full])

    # ------------------------------------------------------------------
    # cache I/O
    def kernel_inputs(self):
        """The pool in the attention kernel's expected layout:
        ``(pool, block_tables, cache_len)`` with tables/lengths as
        device int32 arrays. Pool leaves are layer-stacked
        ``[L, NB+1, BS, ...]`` — block-major with ``block_size`` in the
        sequence slot — which is exactly what
        ``Model.decode_step_paged``/``verify_step_paged`` (and the
        block-paged Pallas kernel underneath) consume; the extra block
        is the null block dead rows write into.

        The device views are cached against mutation-version counters:
        block tables change only on admission / lazy tail claim /
        truncate / free, so a steady decode step re-uploads nothing but
        the per-row lengths vector — O(max_batch) int32 per step, not
        O(max_batch · blocks_per_row) (the regression test asserts
        table-object identity across pure-decode steps)."""
        if self._dev_tables_version != self._tables_version:
            self._dev_tables = jnp.asarray(self.block_tables)
            self._dev_tables_version = self._tables_version
        if self._dev_len_version != self._len_version:
            self._dev_len = jnp.asarray(self.cache_len)
            self._dev_len_version = self._len_version
        return (self.pool, self._dev_tables, self._dev_len)

    def gather_prefix(self, hit_ids: list[int]):
        """(k, v) [L, 1, h, KV, hd] — a hit chain's post-RoPE KV rows,
        dense, for ``Model.prefill_with_prefix``. int8 pools dequantize
        here (per-vector scales live beside the values), so the suffix
        prefill always sees dense K/V whatever the cache dtype."""
        from repro.models import attention as attn

        # pad the chain to blocks_per_row (repeating the last id) so the
        # gather runs at ONE fixed shape whatever the hit length — hit
        # lengths vary request to request, and a per-length eager
        # compile would land in the serving window; the padded tail is
        # sliced off on the host
        h = len(hit_ids) * self.block_size
        ids = list(hit_ids) + [hit_ids[-1]] * (self.blocks_per_row - len(hit_ids))
        table = jnp.asarray(self._slot[np.array(ids, np.int32)][None, :])
        k = attn.gather_block_rows(self.pool["k"], table)
        v = attn.gather_block_rows(self.pool["v"], table)
        if self.model.cfg.kv_quant:
            dt = jnp.dtype(self.model.cfg.dtype)
            k = attn.dequantize_kv(
                k, attn.gather_block_rows(self.pool["k_scale"], table), dt
            )
            v = attn.dequantize_kv(
                v, attn.gather_block_rows(self.pool["v_scale"], table), dt
            )
        return np.asarray(k)[:, :, :h], np.asarray(v)[:, :, :h]

    def write_prefill(self, row: int, dense_cache, skip_blocks: int = 0) -> None:
        """Install a request's batch=1 dense prefill cache into its fresh
        prompt blocks. ``skip_blocks`` leading blocks are a prefix hit —
        already in the pool, shared, and immutable, so they are not
        rewritten."""
        if self._row_owner[row] is None:
            raise RuntimeError(f"write into free row {row}")
        bs = self.block_size
        n_prompt = self.blocks_for(int(self.cache_len[row]))
        ids = self._row_blocks[row][skip_blocks:n_prompt]
        if not ids:
            return
        # one fixed-shape scatter per pool leaf: the index vector is
        # padded to blocks_per_row by repeating the last block id with
        # its own (identical) payload, so every install — any prompt
        # length, any prefix-hit skip — reuses the same compiled op
        # instead of paying an eager compile per (skip, n) combination
        pad = self.blocks_per_row - len(ids)
        idx = jnp.asarray(self._slot[np.array(list(ids) + [ids[-1]] * pad, np.int32)])
        for name, leaf in self.pool.items():
            d = np.asarray(dense_cache[name])  # [L, 1, S_dense, ...]
            L, _, Sd = d.shape[:3]
            blocks = d.reshape((L, Sd // bs, bs) + d.shape[3:])
            src = blocks[:, skip_blocks:n_prompt]
            if pad:
                src = np.concatenate(
                    [src, np.repeat(src[:, -1:], pad, axis=1)], axis=1
                )
            self.pool[name] = leaf.at[:, idx].set(jnp.asarray(src.astype(leaf.dtype)))

    def ensure_tail(self, row: int) -> None:
        """Make sure the row's next decode write position has a physical
        block, claiming one lazily from its reservation if not."""
        self.ensure_tail_n(row, 1)

    def ensure_tail_n(self, row: int, n: int) -> None:
        """Claim tail blocks so the row's next ``n`` write positions
        (``cache_len .. cache_len+n-1`` — a speculative verify writes
        the pending token plus K drafts at once) are all physically
        backed, drawing lazily on the admission reservation."""
        need = self.blocks_for(int(self.cache_len[row]) + n)
        while len(self._row_blocks[row]) < need:
            bi = len(self._row_blocks[row])
            assert bi < self.blocks_per_row
            assert self._row_outstanding[row] > 0, "tail block was not reserved"
            b = self.allocator.alloc()
            self._row_blocks[row].append(b)
            self.block_tables[row, bi] = self._slot[b]
            self._tables_version += 1
            self._row_outstanding[row] -= 1
            self._outstanding_total -= 1

    def advance(self, row: int) -> None:
        self.cache_len[row] += 1
        self._len_version += 1

    def advance_n(self, row: int, n: int) -> None:
        """Account ``n`` KV entries written by one verify call (the
        pending token + K drafts); ``truncate_row`` then rewinds the
        rejected tail."""
        self.cache_len[row] += n
        self._len_version += 1

    def truncate_row(self, row: int, n_rejected: int) -> None:
        """Rewind ``n_rejected`` rejected draft entries off the row's
        tail: the committed length drops, and claimed tail blocks past
        the new length are un-claimed — returned to the allocator with
        their worst-case reservation restored, so a later verify can
        claim them again. Only exclusively-owned, unregistered tail
        blocks can ever be released: verify writes land strictly past
        the prompt, so the rewind is bounded above the shared/registered
        prefix blocks (asserted)."""
        if self._row_owner[row] is None:
            raise RuntimeError(f"truncate of free row {row}")
        new_len = int(self.cache_len[row]) - int(n_rejected)
        assert new_len >= 0, "truncate below zero"
        self.cache_len[row] = new_len
        self._len_version += 1
        keep = self.blocks_for(new_len)
        while len(self._row_blocks[row]) > keep:
            b = self._row_blocks[row].pop()
            assert self.allocator.refcount[b] == 1 and (
                self.prefix is None or not self.prefix.registered(b)
            ), "truncate reached a shared/registered block"
            self.allocator.free(b)
            self.block_tables[row, len(self._row_blocks[row])] = self.null_block
            self._tables_version += 1
            self._row_outstanding[row] += 1
            self._outstanding_total += 1

    # ------------------------------------------------------------------
    def free_row(self, row: int) -> None:
        """Retire a request: drop one referent per block (shared prefix
        blocks survive under their other referents; registered blocks
        with no referents park in the LRU bench for future prefix hits),
        release the unclaimed reservation, reset the table row."""
        if not 0 <= row < self.max_batch:
            raise IndexError(f"row {row} out of range")
        if self._row_owner[row] is None:
            raise RuntimeError(f"double free of row {row}")
        for b in self._row_blocks[row]:
            self.allocator.free(
                b, park=self.prefix is not None and self.prefix.registered(b)
            )
        self._outstanding_total -= self._row_outstanding[row]
        self._row_outstanding[row] = 0
        self._row_blocks[row] = []
        self._row_owner[row] = None
        self.block_tables[row, :] = self.null_block
        self.cache_len[row] = 0
        self._tables_version += 1
        self._len_version += 1
        bisect.insort(self._row_free, row)

    def preempt_row(self, row: int, tokens=None) -> None:
        """Evict a live row under block pressure, keeping its work.

        ``tokens`` (prompt + committed generated tokens) registers the
        row's full blocks in the prefix trie *before* the row frees, so
        they park instead of vanishing: resumption prefix-matches the
        whole committed history and recomputes only the partial tail
        block — suffix-only recompute, not a cold prefill. Blocks whose
        chain already exists in the trie keep their first registration
        (``PrefixCache.insert`` dedups); such duplicates stay private
        and return to the free list. Without ``tokens`` (or without a
        trie) this is a plain eviction."""
        if self._row_owner[row] is None:
            raise RuntimeError(f"preempt of free row {row}")
        if self.prefix is not None and tokens is not None:
            n_full = min(len(tokens) // self.block_size, len(self._row_blocks[row]))
            if n_full:
                self.prefix.insert(
                    tuple(tokens)[: n_full * self.block_size],
                    self._row_blocks[row][:n_full],
                )
        self.free_row(row)

    def drop_cached(self) -> int:
        """Evict every parked (cached, unreferenced) block — test/ops
        hook that restores the cold path. Returns how many were evicted."""
        n = 0
        while self.allocator.n_parked:
            self.allocator.evict(self.allocator.parked_lru()[0])
            n += 1
        return n

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Rows and blocks stay consistent: the allocator partition
        holds, per-row tables mirror the owned-block lists, every block
        referent is exactly one row, and reservations never exceed what
        the allocator can still provide."""
        self.allocator.check_invariants()
        live_rows = {i for i, o in enumerate(self._row_owner) if o is not None}
        free_rows = set(self._row_free)
        assert not (free_rows & live_rows), "row both free and live"
        assert free_rows | live_rows == set(range(self.max_batch)), "row leaked"
        refs = [0] * self.num_blocks
        for row in range(self.max_batch):
            blocks = self._row_blocks[row]
            if row not in live_rows:
                assert not blocks and self._row_outstanding[row] == 0
            for j, b in enumerate(blocks):
                assert self.block_tables[row, j] == self._slot[b], (
                    "table/block-list skew"
                )
                refs[b] += 1
            assert (self.block_tables[row, len(blocks):] == self.null_block).all()
        assert refs == self.allocator.refcount, "refcounts not conserved"
        assert self._outstanding_total == sum(self._row_outstanding)
        assert self.allocator.n_available >= self._outstanding_total, (
            "reserved more blocks than the pool can still provide"
        )
