"""Slotted KV-cache pool for continuous batching.

Owns a fixed pool of ``max_batch`` decode-cache slots (one
``Model.init_cache(max_batch, max_seq)`` allocation, made once). Slots
are allocated when a request is admitted and freed when it finishes or
hits EOS; the decode step always runs over the *whole* pool, so its jit
shape never changes — liveness is the ``live_mask`` the masked plan
execution consumes (DESIGN.md §3).

All per-family slot logic rides on ``Model.cache_batch_axes`` /
``read_cache_slot`` / ``write_cache_slot`` (the batch-axis metadata next
to ``cache_axes``), so this module never inspects cache leaves itself.
"""
from __future__ import annotations

import bisect
from typing import Optional


class SlotKVCache:
    """Fixed pool of cache slots: allocate on admit, free on finish."""

    def __init__(self, model, max_batch: int, max_seq: int, dtype=None):
        self.model = model
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cache = model.init_cache(self.max_batch, self.max_seq, dtype=dtype)
        self._free: list[int] = list(range(self.max_batch))  # ascending
        self._owner: list[Optional[int]] = [None] * self.max_batch  # slot → rid

    # ------------------------------------------------------------------
    # occupancy
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.max_batch - len(self._free)

    def owner(self, slot: int) -> Optional[int]:
        return self._owner[slot]

    def live_mask(self):
        """[max_batch] bool — which slots hold live requests."""
        import numpy as np

        return np.array([o is not None for o in self._owner])

    def live_slots(self) -> list[int]:
        return [i for i, o in enumerate(self._owner) if o is not None]

    # ------------------------------------------------------------------
    # slot lifecycle
    def alloc(self, rid: int) -> int:
        """Claim the lowest free slot for request ``rid``."""
        if not self._free:
            raise RuntimeError("no free cache slot (pool exhausted)")
        slot = self._free.pop(0)
        self._owner[slot] = rid
        return slot

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.max_batch:
            raise IndexError(f"slot {slot} out of range")
        if self._owner[slot] is None:
            raise RuntimeError(f"double free of slot {slot}")
        self._owner[slot] = None
        bisect.insort(self._free, slot)

    # ------------------------------------------------------------------
    # cache I/O (family-agnostic, via the model's batch-axis metadata)
    def write(self, slot: int, slot_cache) -> None:
        """Install a batch=1 cache (a request's prefill) into ``slot``."""
        if self._owner[slot] is None:
            raise RuntimeError(f"write into free slot {slot}")
        self.cache = self.model.write_cache_slot(self.cache, slot_cache, slot)

    def read(self, slot: int):
        """Slot ``slot`` as a batch=1 cache."""
        return self.model.read_cache_slot(self.cache, slot)

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Free slots and live slots partition the pool; the free list is
        sorted and duplicate-free (used by the property tests)."""
        live = {i for i, o in enumerate(self._owner) if o is not None}
        free = set(self._free)
        assert len(self._free) == len(free), "duplicate in free list"
        assert not (free & live), "slot both free and live"
        assert free | live == set(range(self.max_batch)), "slot leaked"
        assert self._free == sorted(self._free), "free list unsorted"
