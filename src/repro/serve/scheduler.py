"""Plan-aware continuous-batching scheduler over a slotted or paged pool.

Each ``step()`` (the serving analogue of one Relic task-queue tick):

  1. admits arrived queued requests — per-request prefill, written into
     the pool, first token sampled from the prefill logits (that instant
     is the request's TTFT). Slotted admission charges one slot per
     request; paged admission charges *blocks* (worst case reserved,
     physical blocks claimed lazily) and, on a prefix-cache hit,
     prefills only the un-cached prompt suffix — shared blocks are
     aliased, which is where the shared-prompt TTFT drop comes from;
  2. runs ONE batched decode over the full fixed-shape row pool —
     through the engine's accepted ``RegionPlan`` via masked execution
     when one is set (slotted layout), or through the block tables
     (paged layout) — so neither jit nor the plan retraces as the
     number of live requests changes (liveness, block tables, and
     per-row lengths are data, not shape);
  3. samples the next token per live row, retires requests that hit
     their token budget or EOS, and frees their slots/blocks.

With speculation on (``spec=SpecConfig(k>0)``), step 2 becomes ONE
fused draft→verify round over the same fixed-shape pool: the draft
stream proposes K tokens per row, a single ``verify_step`` forward
prices all of them, greedy-equivalence acceptance commits the matched
prefix plus the corrected token, and the KV pools (target and any
draft-model pool) rewind the rejected tail via ``truncate_row``.
Acceptance counts are data, not shape — one verify trace per depth K
serves every acceptance pattern (DESIGN.md §3.2).

Dead rows still flow through the decode (static shapes); their outputs
are ignored (plain path), zeroed (masked plan path), or routed to the
null block (paged writes). Greedy decoding is batch-size independent
per row, so a half-full continuous batch reproduces the fixed-batch
baseline token-for-token — and the paged gather/scatter and the
speculative draft→verify→rollback round both reproduce the plain
greedy stream bitwise — the properties the serving tests pin.

Driving is open-loop: ``run()`` injects requests at their
``arrival_time`` regardless of completions, which is the honest way to
load a latency-critical server (closed-loop drivers hide queueing).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops
from repro.serve.kv_cache import PagedKVCache, SlotKVCache
from repro.serve.request import DECODE, FINISHED, PREFILL, Request, ServeStats


class Scheduler:
    def __init__(
        self,
        model,
        params,
        *,
        max_batch: int,
        max_seq: int,
        temperature: float = 0.0,
        decode_plan=None,
        stats: Optional[ServeStats] = None,
        seed: int = 0,
        kv_layout: str = "slot",
        block_size: int = 8,
        num_blocks: Optional[int] = None,
        prefix_cache: bool = True,
        spec=None,
        attention_backend: Optional[str] = None,
        prefill_fn=None,
        decode_fn=None,
        paged_decode_fn=None,
        prefix_prefill_fn=None,
        verify_fn=None,
        paged_verify_fn=None,
        plan_step_cache: Optional[dict] = None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.temperature = float(temperature)
        self.seed = seed
        if kv_layout not in ("slot", "paged"):
            raise ValueError(f"kv_layout must be 'slot' or 'paged', got {kv_layout!r}")
        self.kv_layout = kv_layout
        if kv_layout == "paged":
            if decode_plan is not None:
                raise ValueError(
                    "decode plans route through the slotted layout; "
                    "use kv_layout='slot' to serve through a RegionPlan"
                )
            self.kv = PagedKVCache(
                model,
                max_batch,
                max_seq,
                block_size=block_size,
                num_blocks=num_blocks,
                prefix_cache=prefix_cache,
            )
        else:
            self.kv = SlotKVCache(model, max_batch, max_seq)
        # resolve the decode/verify attention backend ONCE, before any
        # jit: the jitted step family binds it statically, so backend
        # choice can never leak between traces (DESIGN.md §4). Engine-
        # made schedulers receive already-bound fns instead.
        self.attention_backend = kernel_ops.resolve_attention_backend(attention_backend)
        self.stats = stats if stats is not None else ServeStats()
        self._queue: list[Request] = []  # sorted by (arrival_time, rid)
        self._active: dict[int, Request] = {}  # row → request
        self._n_admitted = 0  # per-run sampling-key ordinal (not the global rid)
        self._ordinals: dict[int, int] = {}  # rid → ordinal, admission → first sample
        self._tok = jnp.zeros((max_batch,), jnp.int32)  # last token per row
        self._keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(max_batch, dtype=jnp.uint32))
        # jitted steps are engine-owned when schedulers are engine-made, so
        # repeated generate()/serve() calls reuse compiled executables
        self._prefill = prefill_fn or jax.jit(
            lambda p, t, **kw: model.prefill(p, t, max_seq, **kw)
        )
        be = self.attention_backend
        self._decode = decode_fn or model.jit_step("decode_step", be)
        self._decode_paged = paged_decode_fn or (
            model.jit_step("decode_step_paged", be) if kv_layout == "paged" else None
        )
        self._prefill_prefix = prefix_prefill_fn or (
            jax.jit(lambda p, t, pk, pv: model.prefill_with_prefix(p, t, pk, pv, max_seq))
            if kv_layout == "paged"
            else None
        )
        # speculative decode: a draft stream + the fused verify step
        self.spec = spec if (spec is not None and spec.k > 0) else None
        self._drafter = None
        self._verify = self._verify_paged = None
        if self.spec is not None:
            from repro.models.model import SPEC_FAMILIES

            if model.cfg.family not in SPEC_FAMILIES:
                raise ValueError(
                    f"speculative decode needs a {SPEC_FAMILIES} family "
                    f"(rewindable KV cache), got {model.cfg.family!r}"
                )
            if self.temperature > 0.0:
                raise ValueError(
                    "speculative decode is greedy-equivalence only; "
                    "serve with temperature=0"
                )
            if decode_plan is not None:
                raise ValueError(
                    "speculation and decode plans both rewrite the decode "
                    "step — set one or the other"
                )
            self._drafter = self.spec.make_drafter(attention_backend=be)
            self._drafter.bind(max_batch, max_seq)
            self._verify = verify_fn or model.jit_step("verify_step", be)
            self._verify_paged = paged_verify_fn or (
                model.jit_step("verify_step_paged", be) if kv_layout == "paged" else None
            )
        self._plan_steps = plan_step_cache if plan_step_cache is not None else {}
        self._decode_plan = None
        self._t0: Optional[float] = None
        if decode_plan is not None:
            self.set_decode_plan(decode_plan)

    # ------------------------------------------------------------------
    # plan routing (PR 1 contract, now over the active-slot view)
    def set_decode_plan(self, plan) -> None:
        """Route the pool decode through an accepted ``RegionPlan`` (as
        produced by advising ``decode_region`` — stack combine only,
        since request order is externally visible)."""
        if plan is not None and self.kv_layout == "paged":
            raise ValueError("decode plans are not supported on the paged layout")
        if plan is not None and self.spec is not None:
            raise ValueError(
                "speculation and decode plans both rewrite the decode step "
                "— set one or the other"
            )
        if plan is not None and plan.key.combine != "stack":
            raise ValueError(
                "decode plan must preserve per-request order (combine='stack')"
            )
        self._decode_plan = plan

    def _plan_decode(self, cache, tok, mask):
        cache_key = (self._decode_plan.key, self.kv.max_batch)
        if cache_key not in self._plan_steps:
            # pool spec is invariant across steps: fold the batch-axis
            # shuffling into one jitted step; the plan's masked executor
            # keeps a single trace across live-count changes, and the
            # step itself is cached per (plan, pool size) — engine-wide
            # when the scheduler is engine-made
            leaves, treedef = jax.tree.flatten(cache)
            axes = tuple(jax.tree.leaves(self.model.cache_batch_axes(cache)))
            assert len(axes) == len(leaves)
            plan = self._decode_plan

            def step(cache, tok, mask):
                leaves = jax.tree.leaves(cache)
                items = (tok, [jnp.moveaxis(l, ax, 0) for l, ax in zip(leaves, axes)])
                logits, new_leaves = plan.execute_masked(items, mask)
                new_cache = jax.tree.unflatten(
                    treedef,
                    [jnp.moveaxis(l, 0, ax) for l, ax in zip(new_leaves, axes)],
                )
                return logits, new_cache

            self._plan_steps[cache_key] = jax.jit(step)
        return self._plan_steps[cache_key](cache, tok, mask)

    # ------------------------------------------------------------------
    # clock: seconds since run start (arrival_time's frame)
    def _clock(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    # ------------------------------------------------------------------
    # lifecycle transitions
    @property
    def _spec_margin(self) -> int:
        """Row capacity a speculative verify can transiently overhang:
        the last verify before a request retires may write K rejected
        entries past its final committed length."""
        return self.spec.k if self.spec is not None else 0

    def submit(self, req: Request) -> None:
        need = int(jnp.asarray(req.prompt).shape[0]) + req.max_new_tokens
        if req.patch_embeds is not None:
            need += int(jnp.asarray(req.patch_embeds).shape[0])
        need += self._spec_margin
        if need > self.max_seq:
            # past max_seq the cache write clamps and silently corrupts
            # the newest KV entry — fail loudly at submission instead
            margin = (
                f" (incl. speculative margin K={self._spec_margin})"
                if self._spec_margin
                else ""
            )
            raise ValueError(
                f"request {req.rid}: prompt + max_new_tokens = {need}{margin} "
                f"exceeds the row capacity max_seq={self.max_seq}"
            )
        if self.kv_layout == "paged":
            # a request whose block budget can never fit would sit at the
            # queue head forever (admission is FIFO) — reject it loudly,
            # in the block-granular currency admission actually charges
            nb = self.kv.blocks_for(need)
            if nb > self.kv.num_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {nb} KV blocks "
                    f"({need} tokens at block_size={self.kv.block_size}) but the "
                    f"pool holds {self.kv.num_blocks} blocks total "
                    f"({self.kv.n_free_blocks} free) — it can never be admitted"
                )
        req.state = "queued"
        self._queue.append(req)
        self._queue.sort(key=lambda r: (r.arrival_time, r.rid))

    def _sample_row(self, logits_row, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits_row, axis=-1)
        return jax.random.categorical(key, logits_row / self.temperature, axis=-1)

    def _start_decode(self, req: Request, row: int, logits_row, now: float) -> None:
        """Shared admission tail: sample the first token from the prefill
        logits (TTFT is this instant) and arm the decode row."""
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.seed), self._ordinals.pop(req.rid)
        )
        key, sub = jax.random.split(key)
        tok0 = int(self._sample_row(logits_row, sub))
        req.t_first = self._clock()  # first token exists from here
        req.tokens.append(tok0)
        req.state = DECODE
        self._tok = self._tok.at[row].set(tok0)
        self._keys = self._keys.at[row].set(key)
        self._active[row] = req
        if len(req.tokens) >= req.max_new_tokens or tok0 == req.eos_id:
            self._retire(req, self._clock())

    def _admit(self, reqs: list, now: float) -> None:
        """Admit a wave of arrived requests into slots: same-shape prompts
        prefill as ONE batched call (the fixed-batch ``generate()`` wave
        is a single batch-B prefill, as before the scheduler existed),
        each row then written into its own slot via ``read_cache_slot``."""
        for req in reqs:
            # key by the per-run admission ordinal, not the process-global
            # rid: the same seed reproduces the same tokens across runs
            self._ordinals[req.rid] = self._n_admitted
            self._n_admitted += 1
            req.state, req.t_admit = PREFILL, now
        groups: dict = {}
        for req in reqs:
            pe = None if req.patch_embeds is None else tuple(jnp.asarray(req.patch_embeds).shape)
            groups.setdefault((int(jnp.asarray(req.prompt).shape[0]), pe), []).append(req)
        for (_, pe), group in groups.items():
            kw = {}
            if pe is not None:
                kw["patch_embeds"] = jnp.stack([jnp.asarray(r.patch_embeds) for r in group])
            prompts = jnp.stack([jnp.asarray(r.prompt) for r in group])
            logits, cache = self._prefill(self.params, prompts, **kw)
            for i, req in enumerate(group):
                slot = self.kv.alloc(req.rid)
                req.slot = slot
                self.kv.write(slot, self.model.read_cache_slot(cache, i))
                self._start_decode(req, slot, logits[i], now)
                if self._drafter is not None and not req.finished:
                    self._drafter.on_admit(slot, req)

    def _try_admit_paged(self, req: Request, now: float) -> bool:
        """Paged admission, one request at a time: prefix-match the
        prompt, charge the block budget, prefill only the un-cached
        suffix on a hit. Returns False when the row/block budget does
        not fit yet (the request stays queued)."""
        prompt = np.asarray(req.prompt)
        n_cache = len(prompt)
        tokens = tuple(int(t) for t in prompt)
        if req.patch_embeds is not None:
            # patch embeddings occupy cache rows ahead of the tokens and
            # are not token-addressable — no prefix matching for them
            n_cache += int(jnp.asarray(req.patch_embeds).shape[0])
            tokens = ()
        # the block budget carries the speculative margin: the rejected
        # tail of a verify transiently occupies blocks past the final
        # committed length, and lazy tail claims must never fail
        got = self.kv.try_admit(
            req.rid, tokens, req.max_new_tokens + self._spec_margin, n_tokens=n_cache
        )
        if got is None:
            return False
        row, hit_ids = got
        self._ordinals[req.rid] = self._n_admitted
        self._n_admitted += 1
        req.state, req.t_admit = PREFILL, now
        req.slot = row
        hit = len(hit_ids) * self.kv.block_size
        req.prefix_hit = hit
        if hit:
            pk, pv = self.kv.gather_prefix(hit_ids)
            logits, cache = self._prefill_prefix(
                self.params, jnp.asarray(prompt[hit:])[None, :], pk, pv
            )
        else:
            kw = {}
            if req.patch_embeds is not None:
                kw["patch_embeds"] = jnp.asarray(req.patch_embeds)[None]
            logits, cache = self._prefill(self.params, jnp.asarray(prompt)[None, :], **kw)
        self.kv.write_prefill(row, cache, skip_blocks=len(hit_ids))
        self._start_decode(req, row, logits[0], now)
        if self._drafter is not None and not req.finished:
            self._drafter.on_admit(row, req)
        return True

    def _retire(self, req: Request, now: float) -> None:
        req.state, req.t_finish = FINISHED, now
        self.stats.record(req)
        if self.kv_layout == "paged":
            self.kv.free_row(req.slot)
        else:
            self.kv.free(req.slot)
        del self._active[req.slot]

    # ------------------------------------------------------------------
    def _decode_pool(self, mask):
        """One batched decode over the full row pool; returns logits and
        installs the new cache."""
        if self.kv_layout == "paged":
            for row in self._active:
                self.kv.ensure_tail(row)
            pool, tables, lens = self.kv.kernel_inputs()
            logits, new_pool = self._decode_paged(
                self.params, pool, tables, lens, self._tok[:, None]
            )
            logits.block_until_ready()
            self.kv.pool = new_pool
            return logits
        if self._decode_plan is not None:
            logits, new_cache = self._plan_decode(
                self.kv.cache, self._tok, jnp.asarray(mask)
            )
        else:
            logits, new_cache = self._decode(
                self.params, self.kv.cache, self._tok[:, None]
            )
        logits.block_until_ready()
        self.kv.cache = new_cache
        return logits

    def _spec_step(self) -> None:
        """One fused draft→verify speculation round over the full pool.

        The draft stream proposes K tokens per row; ONE ``verify_step``
        forward (fixed [max_batch, K+1] shape — acceptance is data)
        returns per-position target logits; greedy-equivalence
        acceptance commits each row's matched draft prefix plus the
        corrected argmax token, so the emitted stream is token-for-token
        the plain greedy stream. The KV pools then rewind the rejected
        tail: slot lengths truncate in one vectorized update, paged rows
        release their un-needed claimed tail blocks, and a stateful
        drafter rolls back by the same per-row vector (DESIGN.md §3.2).
        """
        K = self.spec.k
        t_start = time.perf_counter()
        drafts = self._drafter.propose(self._active, np.asarray(self._tok))
        t_draft = time.perf_counter()
        self.stats.draft_ms.append((t_draft - t_start) * 1e3)
        tokens_in = jnp.concatenate(
            [self._tok[:, None], jnp.asarray(drafts, jnp.int32)], axis=1
        )
        if self.kv_layout == "paged":
            for row in self._active:
                self.kv.ensure_tail_n(row, K + 1)
            pool, tables, lens = self.kv.kernel_inputs()
            logits, new_pool = self._verify_paged(
                self.params, pool, tables, lens, tokens_in
            )
            logits.block_until_ready()
            self.kv.pool = new_pool
        else:
            logits, new_cache = self._verify(self.params, self.kv.cache, tokens_in)
            logits.block_until_ready()
            self.kv.cache = new_cache
        greedy = np.asarray(jnp.argmax(logits, axis=-1))  # [max_batch, K+1]
        now = time.perf_counter()
        self.stats.verify_ms.append((now - t_draft) * 1e3)
        self.stats.step_ms.append((now - t_start) * 1e3)
        self.stats.spec_k = K
        self.stats.spec_steps += 1

        # acceptance: commit matched prefix + corrected token, per row
        rej = np.full((self.kv.max_batch,), K + 1, np.int32)
        for row, req in list(self._active.items()):
            d, g = drafts[row], greedy[row]
            a = 0
            while a < K and d[a] == g[a]:
                a += 1
            self.stats.spec_proposed += K
            self.stats.spec_accepted += a
            stream = [int(t) for t in d[:a]] + [int(g[a])]
            done = False
            for t in stream:
                req.tokens.append(t)
                if len(req.tokens) >= req.max_new_tokens or t == req.eos_id:
                    done = True
                    break
            if done:
                # budget/EOS mid-stream: the row retires, its junk tail
                # (and, paged, all its blocks) goes with it
                self._retire(req, self._clock())
            else:
                self._tok = self._tok.at[row].set(stream[-1])
                # valid new entries: the pending token + a accepted
                # drafts (the corrected token is pending, not cached)
                rej[row] = K - a
                if self.kv_layout == "paged":
                    self.kv.advance_n(row, K + 1)
                    self.kv.truncate_row(row, K - a)
        if self.kv_layout != "paged":
            # dead/retired rows truncate the full verify width, so their
            # lengths return to the pre-verify value and never drift
            self.kv.truncate_rows(rej)
        self._drafter.rollback(rej)

    def step(self, now: Optional[float] = None) -> bool:
        """Admit arrived requests, then run one batched decode over the
        live set. Returns False when there was nothing to do."""
        if now is None:
            now = self._clock()
        admitted = False
        if self.kv_layout == "paged":
            while self._queue and self._queue[0].arrival_time <= now:
                if not self._try_admit_paged(self._queue[0], now):
                    break
                self._queue.pop(0)
                admitted = True
        else:
            wave = []
            while (
                self._queue
                and self._queue[0].arrival_time <= now
                and len(wave) < self.kv.n_free
            ):
                wave.append(self._queue.pop(0))
            if wave:
                self._admit(wave, now)
                admitted = True
        if not self._active:
            return admitted
        if self.spec is not None:
            self._spec_step()
            return True

        mask = self.kv.live_mask()
        t0 = time.perf_counter()
        logits = self._decode_pool(mask)
        self.stats.step_ms.append((time.perf_counter() - t0) * 1e3)
        if self.kv_layout == "paged":
            for row in self._active:
                self.kv.advance(row)

        keys, subs = jax.vmap(jax.random.split, out_axes=1)(self._keys)
        nxt = jax.vmap(self._sample_row)(logits, subs)
        live = jnp.asarray(mask)
        self._tok = jnp.where(live, nxt, self._tok)
        self._keys = jnp.where(live[:, None], keys, self._keys)
        nxt_host = np.asarray(nxt)
        for row, req in list(self._active.items()):
            tok = int(nxt_host[row])
            req.tokens.append(tok)
            if len(req.tokens) >= req.max_new_tokens or tok == req.eos_id:
                self._retire(req, self._clock())
        return True

    def run(self, requests=None, *, reset_stats: bool = True) -> dict:
        """Open-loop drive to completion: submit ``requests``, admit each
        at its ``arrival_time``, decode until everything finishes.
        Returns rid → generated tokens (np.int32)."""
        if reset_stats:
            self.stats.reset()
        self._t0 = time.perf_counter()
        requests = list(requests or [])
        for r in requests:
            self.submit(r)
        while self._queue or self._active:
            if not self._active and self._queue:
                wait = self._queue[0].arrival_time - self._clock()
                if wait > 0:
                    time.sleep(wait)
            self.step()
        return {r.rid: r.output() for r in requests}
