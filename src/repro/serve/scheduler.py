"""Plan-aware continuous-batching scheduler over a slotted or paged pool.

Each ``step()`` (the serving analogue of one Relic task-queue tick):

  1. admits arrived queued requests in strict priority order — prefill
     written into the pool, first token sampled from the prefill logits
     (that instant is the request's TTFT). Slotted admission charges one
     slot per request; paged admission charges *blocks* (worst case
     reserved, physical blocks claimed lazily) and, on a prefix-cache
     hit, prefills only the un-cached prompt suffix — shared blocks are
     aliased, which is where the shared-prompt TTFT drop comes from.
     When the pool is dry and the queue head outranks a live row, the
     lowest-priority row is *preempted*: its committed full blocks
     re-register in the prefix trie (paged), so resumption is a
     suffix-only recompute, not a cold prefill;
  2. with ``chunk_size`` set, spends at most ``chunk_size`` prompt
     tokens of *chunked prefill* work — one ``prefill_chunk`` call per
     in-flight prompt slice, highest-priority first — so a long prompt
     never monopolizes a step: the paper's fine-grained co-scheduling
     argument applied to the decode loop, where the batched decode is
     the latency-critical stream and prefill is the heavy thread that
     must be sliced to interleave (chunk position is data, one trace
     per pow2 chunk bucket);
  3. runs ONE batched decode over the full fixed-shape row pool —
     through the engine's accepted ``RegionPlan`` via masked execution
     when one is set (slotted layout), or through the block tables
     (paged layout) — so neither jit nor the plan retraces as the
     number of live requests changes (liveness, block tables, and
     per-row lengths are data, not shape);
  4. samples the next token per live row, retires requests that hit
     their token budget or EOS, and frees their slots/blocks.

``step_ms`` times the whole step — admission + chunk work + decode —
so a monolithic prefill stall lands in the step tail it actually
causes (the ``serving.p99_step_ms`` the chunked mode exists to kill).

With speculation on (``spec=SpecConfig(k>0)``), step 2 becomes ONE
fused draft→verify round over the same fixed-shape pool: the draft
stream proposes K tokens per row, a single ``verify_step`` forward
prices all of them, greedy-equivalence acceptance commits the matched
prefix plus the corrected token, and the KV pools (target and any
draft-model pool) rewind the rejected tail via ``truncate_row``.
Acceptance counts are data, not shape — one verify trace per depth K
serves every acceptance pattern (DESIGN.md §3.2).

Dead rows still flow through the decode (static shapes); their outputs
are ignored (plain path), zeroed (masked plan path), or routed to the
null block (paged writes). Greedy decoding is batch-size independent
per row, so a half-full continuous batch reproduces the fixed-batch
baseline token-for-token — and the paged gather/scatter and the
speculative draft→verify→rollback round both reproduce the plain
greedy stream bitwise — the properties the serving tests pin.

Driving is open-loop: ``run()`` injects requests at their
``arrival_time`` regardless of completions, which is the honest way to
load a latency-critical server (closed-loop drivers hide queueing).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops
from repro.models.model import (
    CHUNKED_PREFILL_FAMILIES,
    PAD_PREFILL_FAMILIES,
    prefill_bucket,
)
from repro.serve.kv_cache import PagedKVCache, SlotKVCache
from repro.serve.request import (
    DECODE,
    FINISHED,
    PREEMPTED,
    PREFILL,
    Request,
    ServeStats,
)
from repro.serve.telemetry import TID_ADVISER, Telemetry, get_telemetry


@dataclass
class _ChunkState:
    """An in-flight chunked prefill: the request's private batch-1 dense
    cache (seeded from a prefix hit when there was one) plus the cursor
    into its effective prompt. Installed into the pool when ``pos``
    reaches the end."""

    req: Request
    cache: Any  # batch-1 dense cache, len tracks committed chunk rows
    prompt: np.ndarray  # effective prompt (prompt + committed tokens on resume)
    pos: int  # next un-prefilled prompt position (starts at the prefix hit)
    skip_blocks: int  # leading prefix-hit blocks write_prefill must not touch


class Scheduler:
    def __init__(
        self,
        model,
        params,
        *,
        max_batch: int,
        max_seq: int,
        temperature: float = 0.0,
        decode_plan=None,
        stats: Optional[ServeStats] = None,
        seed: int = 0,
        kv_layout: str = "slot",
        block_size: int = 8,
        num_blocks: Optional[int] = None,
        prefix_cache: bool = True,
        spec=None,
        attention_backend: Optional[str] = None,
        chunk_size: Optional[int] = None,
        prefill_fn=None,
        decode_fn=None,
        paged_decode_fn=None,
        prefix_prefill_fn=None,
        verify_fn=None,
        paged_verify_fn=None,
        chunk_prefill_fn=None,
        plan_step_cache: Optional[dict] = None,
        mesh=None,
        telemetry=None,
        controller=None,
        step_fn_resolver=None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.temperature = float(temperature)
        self.seed = seed
        # flight recorder (DESIGN.md §8): engine-provided, explicit, or
        # the module-global default (disabled). `_ton` is the hard
        # off-switch — every instrumentation site below guards on it,
        # so a disabled tracer leaves the hot path as it was.
        self.tel = telemetry if telemetry is not None else get_telemetry()
        # an online controller senses through the windowed metric rings,
        # so a controller-driven scheduler records into a private live
        # Telemetry when the caller left the recorder off — the module-
        # global off-switch contract (disabled ⇒ untouched hot path) is
        # unchanged for controller-less runs (DESIGN.md §9)
        self.controller = controller
        if controller is not None and not self.tel.enabled:
            self.tel = Telemetry(enabled=True, capacity=8192)
        self._ton = bool(self.tel.enabled)
        self.stats = stats if stats is not None else ServeStats()
        if self._ton:
            reg = self.stats.registry
            self._g_queue = reg.gauge("sched.queue_depth")
            self._g_active = reg.gauge("sched.active")
            self._g_occ = reg.gauge("pool.occupancy")
            self._g_free_blocks = reg.gauge("pool.free_blocks")
            self._c_admitted = reg.counter("sched.admitted")
            self._c_retraces = reg.counter("engine.retraces")
            self._s_chunk_util = reg.series("sched.chunk_util")
        self._step_seq = 0
        if kv_layout not in ("slot", "paged"):
            raise ValueError(f"kv_layout must be 'slot' or 'paged', got {kv_layout!r}")
        self.kv_layout = kv_layout
        self._mesh = mesh
        if kv_layout == "paged":
            if decode_plan is not None:
                raise ValueError(
                    "decode plans route through the slotted layout; "
                    "use kv_layout='slot' to serve through a RegionPlan"
                )
            self.kv = PagedKVCache(
                model,
                max_batch,
                max_seq,
                block_size=block_size,
                num_blocks=num_blocks,
                prefix_cache=prefix_cache,
                mesh=mesh,
                metrics=self.stats.registry if self._ton else None,
            )
        else:
            self.kv = SlotKVCache(model, max_batch, max_seq)
        # resolve the decode/verify attention backend ONCE, before any
        # jit: the jitted step family binds it statically, so backend
        # choice can never leak between traces (DESIGN.md §4). Engine-
        # made schedulers receive already-bound fns instead.
        self.attention_backend = kernel_ops.resolve_attention_backend(attention_backend)
        # pow2 prompt-shape bucketing (pad + mask): one prefill trace per
        # bucket instead of one per distinct prompt length
        self._bucket = model.cfg.family in PAD_PREFILL_FAMILIES
        if chunk_size is not None:
            chunk_size = int(chunk_size)
            if chunk_size < 1 or chunk_size & (chunk_size - 1):
                raise ValueError(
                    f"chunk_size must be a power of two >= 1, got {chunk_size} "
                    "(chunks pad to pow2 buckets; a non-pow2 cap would add a "
                    "one-off trace per partial chunk)"
                )
            if model.cfg.family not in CHUNKED_PREFILL_FAMILIES:
                raise ValueError(
                    f"chunked prefill needs a {CHUNKED_PREFILL_FAMILIES} family "
                    f"(length-addressed KV cache), got {model.cfg.family!r}"
                )
        self.chunk_size = chunk_size
        self._queue: list[Request] = []  # sorted by (-priority, arrival_time, rid)
        self._active: dict[int, Request] = {}  # row → request
        self._chunking: dict[int, _ChunkState] = {}  # row → in-flight chunked prefill
        self._n_admitted = 0  # per-run sampling-key ordinal (not the global rid)
        self._ordinals: dict[int, int] = {}  # rid → ordinal, admission → first sample
        self._tok = jnp.zeros((max_batch,), jnp.int32)  # last token per row
        self._keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(max_batch, dtype=jnp.uint32))
        # jitted steps are engine-owned when schedulers are engine-made, so
        # repeated generate()/serve() calls reuse compiled executables
        self._prefill = prefill_fn or jax.jit(
            lambda p, t, **kw: model.prefill(p, t, max_seq, **kw)
        )
        be = self.attention_backend
        self._decode = decode_fn or model.jit_step("decode_step", be)
        self._decode_paged = paged_decode_fn or (
            model.jit_step("decode_step_paged", be) if kv_layout == "paged" else None
        )
        self._prefill_prefix = prefix_prefill_fn or (
            jax.jit(
                lambda p, t, pk, pv, **kw: model.prefill_with_prefix(
                    p, t, pk, pv, max_seq, **kw
                )
            )
            if kv_layout == "paged"
            else None
        )
        self._prefill_chunk = chunk_prefill_fn or (
            model.jit_step("prefill_chunk", be) if chunk_size is not None else None
        )
        # speculative decode: a draft stream + the fused verify step
        self.spec = spec if (spec is not None and spec.k > 0) else None
        self._drafter = None
        self._verify = self._verify_paged = None
        if self.spec is not None:
            from repro.models.model import SPEC_FAMILIES

            if model.cfg.family not in SPEC_FAMILIES:
                raise ValueError(
                    f"speculative decode needs a {SPEC_FAMILIES} family "
                    f"(rewindable KV cache), got {model.cfg.family!r}"
                )
            if self.temperature > 0.0:
                raise ValueError(
                    "speculative decode is greedy-equivalence only; "
                    "serve with temperature=0"
                )
            if decode_plan is not None:
                raise ValueError(
                    "speculation and decode plans both rewrite the decode "
                    "step — set one or the other"
                )
            self._drafter = self.spec.make_drafter(attention_backend=be)
            self._drafter.bind(max_batch, max_seq)
            self._verify = verify_fn or model.jit_step("verify_step", be)
            self._verify_paged = paged_verify_fn or (
                model.jit_step("verify_step_paged", be) if kv_layout == "paged" else None
            )
        # live speculation depth: SpecConfig.k is the *maximum* (it sizes
        # the admission margin, the drafter overhang, and the deepest
        # pre-warmed verify trace); a controller re-decides the serving
        # depth within [0, spec.k] mid-run, retrace-free
        self._live_k = self.spec.k if self.spec is not None else 0
        self._fn_resolver = step_fn_resolver
        self._local_fns: dict[str, dict] = {}  # standalone resolver cache
        self._ctl_steps = 0
        self._admit_budget: Optional[int] = None
        if controller is not None:
            self._wire_controller(controller)
        self._plan_steps = plan_step_cache if plan_step_cache is not None else {}
        self._decode_plan = None
        self._t0: Optional[float] = None
        if decode_plan is not None:
            self.set_decode_plan(decode_plan)
        if self._ton:
            # retrace watch: jitted-step compile-cache sizes, sampled at
            # step boundaries — growth mid-run means a shape escaped its
            # trace family (the no-retrace contract the chunked tests pin).
            # Baseline now: engine-shared fns arrive pre-warmed, and those
            # compiles are not this run's retraces.
            self._rebuild_trace_watch()

    def _rebuild_trace_watch(self) -> None:
        """(Re)collect the jitted step fns under retrace watch and
        re-baseline their compile-cache sizes — called at construction
        and after a live backend swap installs a different fn family."""
        self._traced_fns = [
            f
            for f in (
                self._prefill,
                self._decode,
                self._decode_paged,
                self._prefill_prefix,
                self._prefill_chunk,
                self._verify,
                self._verify_paged,
            )
            if f is not None and hasattr(f, "_cache_size")
        ]
        self._cache_size_seen = sum(f._cache_size() for f in self._traced_fns)

    # ------------------------------------------------------------------
    # online adaptive adviser (DESIGN.md §9): observe → decide → apply
    def _wire_controller(self, controller) -> None:
        """Validate the controller's candidate arms against this
        scheduler's capacity and apply its initial arm.  The deepest
        candidate must fit inside ``spec.k`` (the admission margin and
        drafter overhang were sized for it), and a multi-backend
        controller needs a step-fn resolver (engine-made schedulers get
        the engine's pre-warmed families; standalone ones fall back to
        a scheduler-local cache)."""
        ks = tuple(getattr(controller, "ks", (0,)))
        kmax = max(ks) if ks else 0
        if kmax > 0:
            if self.spec is None:
                raise ValueError(
                    f"controller ks={ks} include positive depths but the "
                    "scheduler has no speculation configured — build it with "
                    "spec=SpecConfig(k=max(ks)) so the margin/drafter cover "
                    "the deepest arm"
                )
            if kmax > self.spec.k:
                raise ValueError(
                    f"controller kmax={kmax} exceeds spec.k={self.spec.k} — "
                    "the admission margin and drafter overhang are sized by "
                    "spec.k, so the deepest candidate must fit inside it"
                )
        backends = getattr(controller, "backends", None)
        if backends is None:
            controller.backends = (self.attention_backend,)
        else:
            # resolve candidate names once (e.g. "kernel" → "interpret"
            # on CPU) so controller arms and scheduler state agree
            controller.backends = tuple(
                dict.fromkeys(
                    kernel_ops.resolve_attention_backend(b, mesh=self._mesh)
                    for b in backends
                )
            )
        init_k = getattr(controller, "initial_k", None)
        if init_k is not None and int(init_k) != self._live_k:
            self._set_live_k(int(init_k))

    def _resolve_fns(self, backend: str) -> dict:
        """Step-fn family for ``backend``: the engine's shared cache
        when this scheduler is engine-made, else a local jit cache (the
        retrace-free switching contract only holds for pre-warmed
        engine families — see ``ServingEngine.prime``)."""
        if self._fn_resolver is not None:
            return self._fn_resolver(backend)
        backend = kernel_ops.resolve_attention_backend(backend, mesh=self._mesh)
        fns = self._local_fns.get(backend)
        if fns is None:
            model = self.model
            fns = {"backend": backend, "decode": model.jit_step("decode_step", backend)}
            if self.kv_layout == "paged":
                fns["decode_paged"] = model.jit_step("decode_step_paged", backend)
            if self.spec is not None:
                fns["verify"] = model.jit_step("verify_step", backend)
                if self.kv_layout == "paged":
                    fns["verify_paged"] = model.jit_step("verify_step_paged", backend)
            if self.chunk_size is not None:
                fns["prefill_chunk"] = model.jit_step("prefill_chunk", backend)
            self._local_fns[backend] = fns
        return fns

    def _set_backend(self, backend: str) -> None:
        """Swap the decode/verify attention backend live: a dictionary
        lookup into the pre-built step family — pool state (KV leaves,
        block tables, lengths) is backend-independent, so nothing else
        moves. The trace watch re-baselines so the swap itself is never
        miscounted as a retrace (and an un-warmed family's first-call
        compiles still are)."""
        if backend == self.attention_backend:
            return
        fns = self._resolve_fns(backend)
        self.attention_backend = fns.get("backend", backend)
        self._decode = fns["decode"]
        self._decode_paged = fns.get("decode_paged", self._decode_paged)
        self._verify = fns.get("verify", self._verify)
        self._verify_paged = fns.get("verify_paged", self._verify_paged)
        self._prefill_chunk = fns.get("prefill_chunk", self._prefill_chunk)
        if self._ton:
            self._rebuild_trace_watch()

    def _set_live_k(self, k: int) -> None:
        """Re-decide the speculation depth live. Every depth in
        [1, spec.k] hits a distinct [max_batch, k+1] verify trace in the
        SAME jitted fn (jit caches per input shape), so after priming
        the transition is free. The stateful-drafter catch-up: rows that
        decoded plain while K was 0 advanced the target cache without
        the draft cache seeing their tokens, so a 0→K transition
        re-syncs every active row via ``on_admit`` (re-prefilling the
        committed history, pow2-bucketed — a bounded, off-hot-path
        cost). K→K′ moves between positive depths need no sync: rollback
        leaves the draft cache exactly on the committed stream."""
        k = int(k)
        if k == self._live_k:
            return
        if k < 0 or (k > 0 and (self.spec is None or k > self.spec.k)):
            cap = self.spec.k if self.spec is not None else 0
            raise ValueError(f"live k={k} outside [0, {cap}]")
        was, self._live_k = self._live_k, k
        if self._drafter is not None and k > 0:
            if hasattr(self._drafter, "set_k"):
                self._drafter.set_k(k)
            if was == 0:
                for row, req in self._active.items():
                    self._drafter.on_admit(row, req)

    def _controller_tick(self) -> None:
        """One observe→decide→apply round, every ``decision_interval``
        working steps: read the windowed sensor vector, let the
        controller price the arms, apply the verdict, and record the
        decision on the telemetry adviser lane + the controller gauges
        (current K/backend, switches, dwell) — the paper's audit trail,
        live."""
        c = self.controller
        self._ctl_steps += 1
        if self._ctl_steps % max(1, int(getattr(c, "decision_interval", 8))):
            return
        summary = self.stats.registry.window_summary(int(getattr(c, "window", 16)))
        d = c.decide(
            summary,
            k_live=self._live_k,
            backend_live=self.attention_backend,
            step=self._ctl_steps,
        )
        self._apply_decision(d)
        reg = self.stats.registry
        reg.counter("controller.decisions").inc()
        if d.switched:
            reg.counter("controller.switches").inc()
        reg.gauge("controller.k").set(float(self._live_k))
        backends = getattr(c, "backends", None) or ()
        reg.gauge("controller.backend_index").set(
            float(backends.index(self.attention_backend))
            if self.attention_backend in backends
            else -1.0
        )
        reg.gauge("controller.dwell_remaining").set(
            float(getattr(c, "dwell_remaining", 0))
        )
        self.stats.controller_info = {
            "decisions": len(getattr(c, "decisions", ())) or self._ctl_steps,
            "switches": int(getattr(c, "n_switches", 0)),
            "k": self._live_k,
            "backend": self.attention_backend,
            "admit_budget": self._admit_budget,
            "dwell_remaining": int(getattr(c, "dwell_remaining", 0)),
        }
        self.tel.tracer.instant(
            "online-decision", "adviser", tid=TID_ADVISER, args=d.to_json()
        )

    def _apply_decision(self, d) -> None:
        """Apply one ``Decision``: backend first (the verify trace the
        new K lands on must belong to the new family), then depth, then
        the admission budget."""
        if d.backend is not None:
            self._set_backend(d.backend)
        if d.k is not None:
            self._set_live_k(d.k)
        self._admit_budget = (
            max(1, int(d.admit_budget)) if d.admit_budget is not None else None
        )

    # ------------------------------------------------------------------
    # plan routing (PR 1 contract, now over the active-slot view)
    def set_decode_plan(self, plan) -> None:
        """Route the pool decode through an accepted ``RegionPlan`` (as
        produced by advising ``decode_region`` — stack combine only,
        since request order is externally visible)."""
        if plan is not None and self.kv_layout == "paged":
            raise ValueError("decode plans are not supported on the paged layout")
        if plan is not None and self.spec is not None:
            raise ValueError(
                "speculation and decode plans both rewrite the decode step "
                "— set one or the other"
            )
        if plan is not None and plan.key.combine != "stack":
            raise ValueError(
                "decode plan must preserve per-request order (combine='stack')"
            )
        self._decode_plan = plan

    def _plan_decode(self, cache, tok, mask):
        cache_key = (self._decode_plan.key, self.kv.max_batch)
        if cache_key not in self._plan_steps:
            # pool spec is invariant across steps: fold the batch-axis
            # shuffling into one jitted step; the plan's masked executor
            # keeps a single trace across live-count changes, and the
            # step itself is cached per (plan, pool size) — engine-wide
            # when the scheduler is engine-made
            leaves, treedef = jax.tree.flatten(cache)
            axes = tuple(jax.tree.leaves(self.model.cache_batch_axes(cache)))
            assert len(axes) == len(leaves)
            plan = self._decode_plan

            def step(cache, tok, mask):
                leaves = jax.tree.leaves(cache)
                items = (tok, [jnp.moveaxis(l, ax, 0) for l, ax in zip(leaves, axes)])
                logits, new_leaves = plan.execute_masked(items, mask)
                new_cache = jax.tree.unflatten(
                    treedef,
                    [jnp.moveaxis(l, 0, ax) for l, ax in zip(new_leaves, axes)],
                )
                return logits, new_cache

            self._plan_steps[cache_key] = jax.jit(step)
        return self._plan_steps[cache_key](cache, tok, mask)

    # ------------------------------------------------------------------
    # clock: seconds since run start (arrival_time's frame)
    def _clock(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    # ------------------------------------------------------------------
    # lifecycle transitions
    @property
    def _spec_margin(self) -> int:
        """Row capacity a speculative verify can transiently overhang:
        the last verify before a request retires may write K rejected
        entries past its final committed length."""
        return self.spec.k if self.spec is not None else 0

    @staticmethod
    def _queue_key(req: Request):
        """Strict priority (higher first), then arrival, then rid."""
        return (-req.priority, req.arrival_time, req.rid)

    def submit(self, req: Request) -> None:
        need = int(jnp.asarray(req.prompt).shape[0]) + req.max_new_tokens
        if req.patch_embeds is not None:
            need += int(jnp.asarray(req.patch_embeds).shape[0])
        need += self._spec_margin
        if self.chunk_size is not None and req.patch_embeds is not None:
            self.stats.rejected_submissions += 1
            raise ValueError(
                f"request {req.rid}: chunked prefill cannot split patch "
                "embeddings (not token-addressable) — serve VLM requests "
                "with chunk_size=None"
            )
        if need > self.max_seq:
            # past max_seq the cache write clamps and silently corrupts
            # the newest KV entry — fail loudly at submission instead
            self.stats.rejected_submissions += 1
            margin = (
                f" (incl. speculative margin K={self._spec_margin})"
                if self._spec_margin
                else ""
            )
            raise ValueError(
                f"request {req.rid}: prompt + max_new_tokens = {need}{margin} "
                f"exceeds the row capacity max_seq={self.max_seq}"
            )
        if self.kv_layout == "paged":
            # a request whose block budget can never fit would sit at the
            # queue head forever (admission is head-of-line) — reject it
            # loudly, in the block currency admission actually charges
            nb = self.kv.blocks_for(need)
            if nb > self.kv.num_blocks:
                self.stats.rejected_submissions += 1
                raise ValueError(
                    f"request {req.rid}: needs {nb} KV blocks "
                    f"({need} tokens at block_size={self.kv.block_size}) but the "
                    f"pool holds {self.kv.num_blocks} blocks total "
                    f"({self.kv.n_free_blocks} free) — it can never be admitted"
                )
        req.state = "queued"
        self._queue.append(req)
        self._queue.sort(key=self._queue_key)
        if self._ton:
            self.tel.tracer.async_begin(
                "request",
                req.rid,
                "request",
                args={
                    "prompt_len": int(np.asarray(req.prompt).shape[0]),
                    "max_new_tokens": req.max_new_tokens,
                    "priority": req.priority,
                },
            )

    def _sample_row(self, logits_row, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits_row, axis=-1)
        return jax.random.categorical(key, logits_row / self.temperature, axis=-1)

    def _start_decode(self, req: Request, row: int, logits_row, now: float) -> None:
        """Shared admission tail: sample the first token from the prefill
        logits (TTFT is this instant) and arm the decode row."""
        key = jax.random.fold_in(
            jax.random.PRNGKey(self.seed), self._ordinals.pop(req.rid)
        )
        key, sub = jax.random.split(key)
        tok0 = int(self._sample_row(logits_row, sub))
        req.t_first = self._clock()  # first token exists from here
        if self._ton:
            self.tel.tracer.async_instant("first_token", req.rid, "request")
        req.tokens.append(tok0)
        req.state = DECODE
        self._tok = self._tok.at[row].set(tok0)
        self._keys = self._keys.at[row].set(key)
        self._active[row] = req
        if len(req.tokens) >= req.max_new_tokens or tok0 == req.eos_id:
            self._retire(req, self._clock())

    def _note_admitted(self, req: Request, now: float) -> None:
        """Shared admission bookkeeping: queue wait ends at the FIRST
        admission (re-admissions after preemption don't reset it); the
        sampling-key ordinal is assigned once — a resumed request
        continues its saved key chain instead."""
        if req.t_first_admit is None:
            req.t_first_admit = now
        if self._ton:
            self._c_admitted.inc()
            self.tel.tracer.async_instant(
                "resume" if req.tokens else "admit", req.rid, "request"
            )
        if not req.tokens:
            # key by the per-run admission ordinal, not the process-global
            # rid: the same seed reproduces the same tokens across runs
            self._ordinals[req.rid] = self._n_admitted
            self._n_admitted += 1
        req.state, req.t_admit = PREFILL, now

    @staticmethod
    def _effective_prompt(req: Request) -> np.ndarray:
        """The tokens a (re-)prefill must commit: the prompt plus, on
        resume, every generated token except the pending last one (the
        invariant ``committed len = S + n - 1`` — the newest token is
        fed to decode, never pre-written)."""
        prompt = np.asarray(req.prompt)
        if not req.tokens:
            return prompt
        return np.concatenate(
            [prompt, np.asarray(req.tokens[:-1], prompt.dtype)]
        ) if len(req.tokens) > 1 else prompt

    def _resume_decode(self, req: Request, row: int, now: float) -> None:
        """Re-arm a preempted request mid-stream: the pending token and
        the saved per-row sampling key restore, so the continued decode
        is token-identical to the uninterrupted run."""
        del now
        req.state = DECODE
        self._tok = self._tok.at[row].set(int(req.tokens[-1]))
        if req.sample_key is not None:
            self._keys = self._keys.at[row].set(
                jnp.asarray(np.asarray(req.sample_key), jnp.uint32)
            )
        self._active[row] = req

    def _admit(self, reqs: list, now: float) -> None:
        """Admit a wave of arrived requests into slots: same-bucket
        prompts prefill as ONE batched call (pow2 padding makes mixed
        lengths share both the call and the trace), each row then
        written into its own slot via ``read_cache_slot``. Resumed
        requests re-prefill their effective prompt (prompt + committed
        tokens) and continue their stream."""
        for req in reqs:
            self._note_admitted(req, now)
        groups: dict = {}
        for req in reqs:
            eff = self._effective_prompt(req)
            pe = None if req.patch_embeds is None else tuple(jnp.asarray(req.patch_embeds).shape)
            n_lead = 0 if pe is None else pe[0]
            W = len(eff)
            if self._bucket:
                W = prefill_bucket(W)
                if n_lead + W > self.max_seq:  # cache write would clamp
                    W = len(eff)
            groups.setdefault((W, pe), []).append((req, eff))
        for (W, pe), group in groups.items():
            kw = {}
            if pe is not None:
                kw["patch_embeds"] = jnp.stack(
                    [jnp.asarray(r.patch_embeds) for r, _ in group]
                )
            if self._bucket:
                mat = np.zeros((len(group), W), np.int32)
                for i, (_, eff) in enumerate(group):
                    mat[i, : len(eff)] = eff
                prompts = jnp.asarray(mat)
                kw["prompt_len"] = jnp.asarray(
                    [len(eff) for _, eff in group], jnp.int32
                )
            else:
                prompts = jnp.stack([jnp.asarray(eff) for _, eff in group])
            with self.tel.annotate("serve.prefill"):
                logits, cache = self._prefill(self.params, prompts, **kw)
            for i, (req, eff) in enumerate(group):
                slot = self.kv.alloc(req.rid)
                req.slot = slot
                self.kv.write(slot, self.model.read_cache_slot(cache, i))
                if req.tokens:
                    self.stats.recomputed_tokens += len(eff)
                    self._resume_decode(req, slot, now)
                else:
                    self._start_decode(req, slot, logits[i], now)
                if self._drafter is not None and self._live_k > 0 and not req.finished:
                    self._drafter.on_admit(slot, req)

    def _start_chunk_slot(self, req: Request, now: float) -> None:
        """Slotted chunked admission: claim the slot now, prefill later
        in ``chunk_size`` slices. The slot's pool row holds junk until
        the install (its decode outputs are ignored — the row is not in
        ``_active`` — and ``kv.write`` overwrites everything)."""
        self._note_admitted(req, now)
        slot = self.kv.alloc(req.rid)
        req.slot = slot
        eff = self._effective_prompt(req)
        if req.tokens:
            self.stats.recomputed_tokens += len(eff)
        self._chunking[slot] = _ChunkState(
            req=req,
            cache=self.model.init_cache(1, self.max_seq),
            prompt=eff,
            pos=0,
            skip_blocks=0,
        )

    def _try_admit_paged(self, req: Request, now: float) -> bool:
        """Paged admission, one request at a time: prefix-match the
        effective prompt, charge the block budget, prefill only the
        un-cached suffix on a hit (a resumed request's committed blocks
        re-registered at preemption, so its resume is usually one
        partial tail block of recompute). Returns False when the
        row/block budget does not fit yet (the request stays queued).
        With chunking on, admission only *reserves*: the prompt runs
        through ``_prefill_phase`` in ``chunk_size`` slices and the trie
        registration waits until the blocks actually hold KV."""
        resume = bool(req.tokens)
        eff = self._effective_prompt(req)
        n_cache = len(eff)
        tokens = tuple(int(t) for t in eff)
        if req.patch_embeds is not None:
            # patch embeddings occupy cache rows ahead of the tokens and
            # are not token-addressable — no prefix matching for them
            n_cache += int(jnp.asarray(req.patch_embeds).shape[0])
            tokens = ()
        # the block budget carries the speculative margin: the rejected
        # tail of a verify transiently occupies blocks past the final
        # committed length, and lazy tail claims must never fail. A
        # resume charges only the remaining budget (+1: the pending
        # token still needs its row), so S_eff + budget is the same
        # worst case as the fresh admission's.
        budget = req.max_new_tokens + self._spec_margin
        if resume:
            budget -= len(req.tokens) - 1
        got = self.kv.try_admit(
            req.rid,
            tokens,
            budget,
            n_tokens=n_cache,
            register=self.chunk_size is None,
        )
        if got is None:
            return False
        row, hit_ids = got
        self._note_admitted(req, now)
        req.slot = row
        hit = len(hit_ids) * self.kv.block_size
        if resume:
            self.stats.recomputed_tokens += n_cache - hit
        else:
            # prefix_hit stays the FIRST admission's hit: it feeds the
            # prompt-token prefix_hit_rate, where resume recompute
            # accounting would double-count the same prompt tokens
            req.prefix_hit = hit
        if self.chunk_size is not None:
            if hit:
                pk, pv = self.kv.gather_prefix(hit_ids)
                cache = self.model.seed_cache_with_prefix(pk, pv, self.max_seq)
            else:
                cache = self.model.init_cache(1, self.max_seq)
            self._chunking[row] = _ChunkState(
                req=req, cache=cache, prompt=eff, pos=hit,
                skip_blocks=len(hit_ids),
            )
            return True
        prompt_dev = jnp.asarray(eff)
        if hit:
            pk, pv = self.kv.gather_prefix(hit_ids)
            suffix = prompt_dev[hit:]
            Ssuf = int(suffix.shape[0])
            kw = {}
            if self._bucket:
                W = prefill_bucket(Ssuf)
                if hit + W > self.max_seq:
                    W = Ssuf
                padded = np.zeros((1, W), np.int32)
                padded[0, :Ssuf] = np.asarray(suffix)
                suffix = jnp.asarray(padded)[0]
                kw["suffix_len"] = jnp.asarray([Ssuf], jnp.int32)
            with self.tel.annotate("serve.prefill"):
                logits, cache = self._prefill_prefix(
                    self.params, suffix[None, :], pk, pv, **kw
                )
        else:
            kw = {}
            if req.patch_embeds is not None:
                kw["patch_embeds"] = jnp.asarray(req.patch_embeds)[None]
            S = int(prompt_dev.shape[0])
            n_lead = 0 if req.patch_embeds is None else int(
                jnp.asarray(req.patch_embeds).shape[0]
            )
            if self._bucket:
                W = prefill_bucket(S)
                if n_lead + W > self.max_seq:
                    W = S
                padded = np.zeros((1, W), np.int32)
                padded[0, :S] = np.asarray(prompt_dev)
                prompt_dev = jnp.asarray(padded)[0]
                kw["prompt_len"] = jnp.asarray([S], jnp.int32)
            with self.tel.annotate("serve.prefill"):
                logits, cache = self._prefill(self.params, prompt_dev[None, :], **kw)
        self.kv.write_prefill(row, cache, skip_blocks=len(hit_ids))
        if resume:
            self._resume_decode(req, row, now)
        else:
            self._start_decode(req, row, logits[0], now)
        if self._drafter is not None and self._live_k > 0 and not req.finished:
            self._drafter.on_admit(row, req)
        return True

    # ------------------------------------------------------------------
    # priority preemption
    def _maybe_preempt(self, head: Request) -> bool:
        """Evict the lowest-priority live row to make room for ``head``
        — only when ``head`` STRICTLY outranks it (equal priorities
        never preempt each other: that would livelock two requests
        trading the same row). Ties break toward the most recently
        admitted victim (least sunk work lost). Returns True when a row
        was freed (the caller retries admission)."""
        victims = [
            (req.priority, -(req.t_admit or 0.0), -req.rid, row)
            for row, req in self._active.items()
            if req.priority < head.priority
        ]
        if not victims:
            return False
        victims.sort()
        self._preempt(victims[0][3])
        return True

    def _preempt(self, row: int) -> None:
        """Evict a live decode row, keeping its stream resumable: the
        sampling key and generated tokens persist on the request; the
        paged layout re-registers its committed full blocks in the
        prefix trie (they park instead of vanishing), so the resume
        prefix-matches the whole committed history and recomputes only
        the partial tail block."""
        req = self._active.pop(row)
        req.sample_key = np.asarray(self._keys[row])
        committed = None
        if self.kv_layout == "paged" and req.patch_embeds is None:
            committed = tuple(int(t) for t in self._effective_prompt(req))
        self.kv.preempt_row(row, committed)
        req.state = PREEMPTED
        req.slot = None
        req.preemptions += 1
        self.stats.n_preemptions += 1
        if self._ton:
            self.tel.tracer.async_instant(
                "preempt", req.rid, "request",
                args={"committed_tokens": len(req.tokens)},
            )
        self._queue.append(req)
        self._queue.sort(key=self._queue_key)

    def _retire(self, req: Request, now: float) -> None:
        req.state, req.t_finish = FINISHED, now
        self.stats.record(req)
        if self._ton:
            self.tel.tracer.async_end(
                "request",
                req.rid,
                "request",
                args={
                    "tokens": len(req.tokens),
                    "preemptions": req.preemptions,
                    "prefix_hit": req.prefix_hit,
                },
            )
        if self.kv_layout == "paged":
            self.kv.free_row(req.slot)
        else:
            self.kv.free(req.slot)
        del self._active[req.slot]

    # ------------------------------------------------------------------
    def _decode_pool(self, mask):
        """One batched decode over the full row pool; returns logits and
        installs the new cache."""
        if self.kv_layout == "paged":
            for row in self._active:
                self.kv.ensure_tail(row)
            pool, tables, lens = self.kv.kernel_inputs()
            with self.tel.annotate("serve.decode"):
                logits, new_pool = self._decode_paged(
                    self.params, pool, tables, lens, self._tok[:, None]
                )
            logits.block_until_ready()
            self.kv.pool = new_pool
            return logits
        with self.tel.annotate("serve.decode"):
            if self._decode_plan is not None:
                logits, new_cache = self._plan_decode(
                    self.kv.cache, self._tok, jnp.asarray(mask)
                )
            else:
                logits, new_cache = self._decode(
                    self.params, self.kv.cache, self._tok[:, None]
                )
        logits.block_until_ready()
        self.kv.cache = new_cache
        return logits

    def _spec_step(self) -> None:
        """One fused draft→verify speculation round over the full pool.

        The draft stream proposes K tokens per row; ONE ``verify_step``
        forward (fixed [max_batch, K+1] shape — acceptance is data)
        returns per-position target logits; greedy-equivalence
        acceptance commits each row's matched draft prefix plus the
        corrected argmax token, so the emitted stream is token-for-token
        the plain greedy stream. The KV pools then rewind the rejected
        tail: slot lengths truncate in one vectorized update, paged rows
        release their un-needed claimed tail blocks, and a stateful
        drafter rolls back by the same per-row vector (DESIGN.md §3.2).
        """
        K = self._live_k
        t_start = time.perf_counter()
        with self.tel.annotate("serve.draft"):
            drafts = self._drafter.propose(self._active, np.asarray(self._tok))
        t_draft = time.perf_counter()
        self.stats.draft_ms.append((t_draft - t_start) * 1e3)
        tokens_in = jnp.concatenate(
            [self._tok[:, None], jnp.asarray(drafts, jnp.int32)], axis=1
        )
        if self.kv_layout == "paged":
            for row in self._active:
                self.kv.ensure_tail_n(row, K + 1)
            pool, tables, lens = self.kv.kernel_inputs()
            with self.tel.annotate("serve.verify"):
                logits, new_pool = self._verify_paged(
                    self.params, pool, tables, lens, tokens_in
                )
            logits.block_until_ready()
            self.kv.pool = new_pool
        else:
            with self.tel.annotate("serve.verify"):
                logits, new_cache = self._verify(self.params, self.kv.cache, tokens_in)
            logits.block_until_ready()
            self.kv.cache = new_cache
        greedy = np.asarray(jnp.argmax(logits, axis=-1))  # [max_batch, K+1]
        now = time.perf_counter()
        self.stats.verify_ms.append((now - t_draft) * 1e3)
        if self._ton:
            tr = self.tel.tracer
            a, b = tr.to_us(t_start), tr.to_us(t_draft)
            tr.complete("draft", "sched", a, b - a, args={"k": K})
            tr.complete("verify", "sched", b, tr.to_us(now) - b, args={"k": K})
        self.stats.spec_k = K
        self.stats.spec_steps += 1

        # acceptance: commit matched prefix + corrected token, per row
        rej = np.full((self.kv.max_batch,), K + 1, np.int32)
        for row, req in list(self._active.items()):
            d, g = drafts[row], greedy[row]
            a = 0
            while a < K and d[a] == g[a]:
                a += 1
            self.stats.spec_proposed += K
            self.stats.spec_accepted += a
            stream = [int(t) for t in d[:a]] + [int(g[a])]
            done = False
            for t in stream:
                req.tokens.append(t)
                if len(req.tokens) >= req.max_new_tokens or t == req.eos_id:
                    done = True
                    break
            if done:
                # budget/EOS mid-stream: the row retires, its junk tail
                # (and, paged, all its blocks) goes with it
                self._retire(req, self._clock())
            else:
                self._tok = self._tok.at[row].set(stream[-1])
                # valid new entries: the pending token + a accepted
                # drafts (the corrected token is pending, not cached)
                rej[row] = K - a
                if self.kv_layout == "paged":
                    self.kv.advance_n(row, K + 1)
                    self.kv.truncate_row(row, K - a)
        if self.kv_layout != "paged":
            # dead/retired rows truncate the full verify width, so their
            # lengths return to the pre-verify value and never drift
            self.kv.truncate_rows(rej)
        self._drafter.rollback(rej)

    def _admit_phase(self, now: float) -> bool:
        """Admit arrived requests, highest priority first, preempting a
        strictly-lower-priority live row when the pool is dry. The loop
        terminates: each admission consumes capacity and each preemption
        strictly raises the active set's priority multiset, both finite.
        A controller-set ``_admit_budget`` caps admissions per step
        (back-pressure under preemption churn); ``None`` is unlimited."""
        admitted = False
        n_admitted = 0
        budget = self._admit_budget
        while True:
            if budget is not None and n_admitted >= budget:
                return admitted
            arrived = [r for r in self._queue if r.arrival_time <= now]
            if not arrived:
                return admitted
            if self.kv_layout == "paged":
                head = arrived[0]
                if self._try_admit_paged(head, now):
                    self._queue.remove(head)
                    admitted = True
                    n_admitted += 1
                    continue
            else:
                wave = arrived[: self.kv.n_free]
                if budget is not None:
                    wave = wave[: budget - n_admitted]
                if wave:
                    for r in wave:
                        self._queue.remove(r)
                    if self.chunk_size is not None:
                        for r in wave:
                            self._start_chunk_slot(r, now)
                    else:
                        self._admit(wave, now)
                    admitted = True
                    n_admitted += len(wave)
                    continue
                head = arrived[0]
            if not self._maybe_preempt(head):
                return admitted

    def _prefill_phase(self, now: float) -> bool:
        """Spend at most ``chunk_size`` prompt tokens of chunked prefill
        work, highest-priority request first. Each slice is one
        ``prefill_chunk`` call padded to its pow2 bucket (≤ chunk_size)
        — chunk position rides in the cache's ``len``, so walking a
        prompt reuses one trace per bucket. A prompt that completes
        installs into the pool and its first token samples this step."""
        if not self._chunking:
            return False
        budget = self.chunk_size
        while budget > 0 and self._chunking:
            row, st = min(
                self._chunking.items(), key=lambda it: self._queue_key(it[1].req)
            )
            n = min(budget, len(st.prompt) - st.pos)
            W = prefill_bucket(n, self.chunk_size)
            while st.pos + W > self.max_seq:  # pad row would overrun the cache
                W //= 2
            n = min(n, W)
            toks = np.zeros((1, W), np.int32)
            toks[0, :n] = st.prompt[st.pos : st.pos + n]
            with self.tel.annotate("serve.prefill_chunk"):
                logits, st.cache = self._prefill_chunk(
                    self.params, st.cache, jnp.asarray(toks),
                    jnp.asarray([n], jnp.int32),
                )
            st.pos += n
            budget -= n
            if self._ton:
                self.tel.tracer.async_instant(
                    "prefill-chunk", st.req.rid, "request",
                    args={"n": n, "pos": st.pos, "of": len(st.prompt)},
                )
            if st.pos == len(st.prompt):
                del self._chunking[row]
                self._install_chunked(st, row, logits[0, n - 1], now)
        if self._ton:
            # fraction of the per-step token budget actually spent —
            # sustained < 1 with a non-empty queue means admission, not
            # chunk work, is the bottleneck
            self._s_chunk_util.append((self.chunk_size - budget) / self.chunk_size)
        return True

    def _install_chunked(self, st: _ChunkState, row: int, logits_row, now) -> None:
        """A fully-chunked prompt lands in the pool: paged rows write
        their fresh blocks (prefix-hit blocks skipped — already shared
        and immutable) and register the prompt in the trie now that the
        blocks hold real KV; slotted rows install the whole dense cache."""
        req = st.req
        if self.kv_layout == "paged":
            self.kv.write_prefill(row, st.cache, skip_blocks=st.skip_blocks)
            self.kv.register_prompt(row, tuple(int(t) for t in st.prompt))
        else:
            self.kv.write(row, st.cache)
        if req.tokens:
            self._resume_decode(req, row, now)
        else:
            self._start_decode(req, row, logits_row, now)
        if self._drafter is not None and self._live_k > 0 and not req.finished:
            self._drafter.on_admit(row, req)

    def prime(self) -> None:
        """Pre-compile the chunked-prefill trace family: one trace per
        pow2 bucket W ≤ chunk_size. The family is closed — every slice
        ``_prefill_phase`` can emit (full chunks, resume tails, the
        overrun-halved fallback) pads to one of these widths — so a
        primed scheduler never retraces chunk prefill mid-run. No-op
        when chunking is off. The jitted fn is shared through the
        engine's step-fn cache, so priming one scheduler warms every
        later scheduler on the same engine and backend."""
        if self._prefill_chunk is None:
            return
        cache = self.model.init_cache(1, self.max_seq)
        W = 1
        while W <= self.chunk_size:
            logits, _ = self._prefill_chunk(
                self.params, cache, jnp.zeros((1, W), jnp.int32),
                jnp.asarray([W], jnp.int32),
            )
            jax.block_until_ready(logits)
            W *= 2

    def _finish_step(self, t0: float, phases) -> None:
        """Telemetry step boundary (only reached with the tracer on):
        emit the step span + its non-empty phase children, refresh the
        pool/queue gauges, sample the jit caches for retraces, and tick
        the registry so every counter lands in its window ring. All
        host-side bookkeeping — no device syncs beyond the ones the
        step already performed."""
        end = time.perf_counter()
        tr = self.tel.tracer
        ts0 = tr.to_us(t0)
        self._step_seq += 1
        tr.complete(
            "step", "sched", ts0, tr.to_us(end) - ts0,
            args={
                "seq": self._step_seq,
                "active": len(self._active),
                "queued": len(self._queue),
            },
        )
        for name, a, b, did in phases:
            if did:
                ta = tr.to_us(a)
                tr.complete(name, "sched", ta, tr.to_us(b) - ta)
        self._g_queue.set(len(self._queue))
        self._g_active.set(len(self._active))
        self._g_occ.set(self.kv.occupancy)
        if self.kv_layout == "paged":
            self._g_free_blocks.set(self.kv.n_free_blocks)
        size = sum(f._cache_size() for f in self._traced_fns)
        if size > self._cache_size_seen:
            self._c_retraces.inc(size - self._cache_size_seen)
            tr.instant("retrace", "sched", args={"new": size - self._cache_size_seen})
        self._cache_size_seen = size
        self.stats.registry.tick()

    def step(self, now: Optional[float] = None) -> bool:
        """Admit arrived requests, spend the chunked-prefill token
        budget, then run one batched decode over the live set. Returns
        False when there was nothing to do. ``step_ms`` covers the whole
        step, so prefill stalls show up in the tail they cause. With a
        controller attached, every working step also advances the
        observe→decide→apply loop (after the telemetry tick, so the
        decision prices a window that includes this step)."""
        did = self._step_inner(now)
        if did and self.controller is not None:
            self._controller_tick()
        return did

    def _step_inner(self, now: Optional[float] = None) -> bool:
        if now is None:
            now = self._clock()
        t0 = time.perf_counter()
        ton = self._ton
        admitted = self._admit_phase(now)
        t_admit = time.perf_counter() if ton else 0.0
        chunked = self._prefill_phase(now)
        t_chunk = time.perf_counter() if ton else 0.0
        if not self._active:
            if admitted or chunked:
                self.stats.step_ms.append((time.perf_counter() - t0) * 1e3)
                if ton:
                    self._finish_step(
                        t0,
                        (("admit", t0, t_admit, admitted),
                         ("prefill_chunk", t_admit, t_chunk, chunked)),
                    )
                return True
            return False
        if self.spec is not None and self._live_k > 0:
            self._spec_step()
            self.stats.step_ms.append((time.perf_counter() - t0) * 1e3)
            if ton:
                # draft/verify phase spans were emitted inside _spec_step
                self._finish_step(
                    t0,
                    (("admit", t0, t_admit, admitted),
                     ("prefill_chunk", t_admit, t_chunk, chunked)),
                )
            return True

        mask = self.kv.live_mask()
        logits = self._decode_pool(mask)
        t_decode = time.perf_counter() if ton else 0.0
        self.stats.step_ms.append((time.perf_counter() - t0) * 1e3)
        if self.kv_layout == "paged":
            for row in self._active:
                self.kv.advance(row)

        keys, subs = jax.vmap(jax.random.split, out_axes=1)(self._keys)
        nxt = jax.vmap(self._sample_row)(logits, subs)
        live = jnp.asarray(mask)
        self._tok = jnp.where(live, nxt, self._tok)
        self._keys = jnp.where(live[:, None], keys, self._keys)
        nxt_host = np.asarray(nxt)
        for row, req in list(self._active.items()):
            tok = int(nxt_host[row])
            req.tokens.append(tok)
            if len(req.tokens) >= req.max_new_tokens or tok == req.eos_id:
                self._retire(req, self._clock())
        if ton:
            self._finish_step(
                t0,
                (("admit", t0, t_admit, admitted),
                 ("prefill_chunk", t_admit, t_chunk, chunked),
                 ("decode", t_chunk, t_decode, True),
                 ("sample", t_decode, time.perf_counter(), True)),
            )
        return True

    def run(self, requests=None, *, reset_stats: bool = True) -> dict:
        """Open-loop drive to completion: submit ``requests``, admit each
        at its ``arrival_time``, decode until everything finishes.
        Returns rid → generated tokens (np.int32)."""
        if reset_stats:
            self.stats.reset()
        self._t0 = time.perf_counter()
        requests = list(requests or [])
        for r in requests:
            self.submit(r)
        while self._queue or self._active or self._chunking:
            if not self._active and not self._chunking and self._queue:
                # earliest arrival, not queue head: the queue is priority-
                # ordered, so the head may arrive later than a lower-
                # priority request
                wait = min(r.arrival_time for r in self._queue) - self._clock()
                if wait > 0:
                    time.sleep(wait)
            self.step()
        return {r.rid: r.output() for r in requests}
