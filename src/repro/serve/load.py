"""Open-loop load generation for the serving core.

Poisson arrivals (exponential inter-arrival gaps) with randomized prompt
lengths and token budgets — arrivals follow their own schedule
regardless of completions, the honest way to load a latency-critical
server (DESIGN.md §3). Shared by ``benchmarks/serving_load.py`` and
``examples/serve_decode.py`` so the tracked benchmark and the demo never
diverge.
"""
from __future__ import annotations

import numpy as np


def make_requests(
    n: int,
    rate_rps: float,
    *,
    vocab: int,
    max_new_tokens: int,
    prompt_lens=(4, 8, 12, 16),
    rng: np.random.Generator,
):
    """n Poisson-arrival requests at ``rate_rps``, each with a random
    prompt length from ``prompt_lens`` and a random budget in
    [min(2, max_new_tokens), max_new_tokens]."""
    from repro.serve.request import Request

    lo = min(2, max_new_tokens)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    reqs = []
    for i in range(n):
        s0 = int(rng.choice(prompt_lens))
        prompt = rng.integers(0, vocab, size=(s0,)).astype(np.int32)
        reqs.append(
            Request(
                prompt=prompt,
                max_new_tokens=int(rng.integers(lo, max_new_tokens + 1)),
                arrival_time=float(arrivals[i]),
            )
        )
    return reqs
