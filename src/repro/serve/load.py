"""Open-loop load generation for the serving core.

Poisson arrivals (exponential inter-arrival gaps) with randomized prompt
lengths and token budgets — arrivals follow their own schedule
regardless of completions, the honest way to load a latency-critical
server (DESIGN.md §3). Shared by ``benchmarks/serving_load.py`` and
``examples/serve_decode.py`` so the tracked benchmark and the demo never
diverge.
"""
from __future__ import annotations

import numpy as np


def make_requests(
    n: int,
    rate_rps: float,
    *,
    vocab: int,
    max_new_tokens: int,
    prompt_lens=(4, 8, 12, 16),
    rng: np.random.Generator,
):
    """n Poisson-arrival requests at ``rate_rps``, each with a random
    prompt length from ``prompt_lens`` and a random budget in
    [min(2, max_new_tokens), max_new_tokens]."""
    from repro.serve.request import Request

    lo = min(2, max_new_tokens)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    reqs = []
    for i in range(n):
        s0 = int(rng.choice(prompt_lens))
        prompt = rng.integers(0, vocab, size=(s0,)).astype(np.int32)
        reqs.append(
            Request(
                prompt=prompt,
                max_new_tokens=int(rng.integers(lo, max_new_tokens + 1)),
                arrival_time=float(arrivals[i]),
            )
        )
    return reqs


def make_slo_requests(
    n: int,
    rate_rps: float,
    *,
    vocab: int,
    max_new_tokens: int,
    short_lens=(8, 16),
    long_len: int = 96,
    long_every: int = 4,
    short_priority: int = 1,
    long_priority: int = 0,
    rng: np.random.Generator,
):
    """The SLO-attainment workload: Poisson arrivals where every
    ``long_every``-th request is a long, low-priority prompt and the
    rest are short, high-priority interactive requests. The long
    prompts are the monolithic-prefill stall generators (and, under
    block pressure, the preemption victims) whose impact on the short
    requests' TTFT/TPOT the ``serving.slo`` benchmark measures."""
    from repro.serve.request import Request

    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    reqs = []
    for i in range(n):
        long = long_every > 0 and i % long_every == long_every - 1
        s0 = int(long_len) if long else int(rng.choice(short_lens))
        prompt = rng.integers(0, vocab, size=(s0,)).astype(np.int32)
        reqs.append(
            Request(
                prompt=prompt,
                max_new_tokens=int(max_new_tokens),
                arrival_time=float(arrivals[i]),
                priority=int(long_priority if long else short_priority),
            )
        )
    return reqs


def make_drift_requests(
    phase_n=(6, 8, 6),
    rate_rps: float = 60.0,
    *,
    vocab: int,
    rep_tokens: int = 24,
    churn_tokens: int = 3,
    rep_prompt_len: int = 8,
    churn_prompt_lens=(8, 12, 16),
    prefix_len: int = 16,
    suffix_len: int = 6,
    phase_gap_s: float = 0.1,
    rng: np.random.Generator,
    prefix=None,
):
    """The drifting-draftability workload (DESIGN.md §9): three phases
    whose speculation profitability flips, so every static K loses
    somewhere and only a controller tracks the per-phase best arm.

    1. ``repetitive`` — short random prompts with long token budgets:
       tiny greedy models settle into repeating cycles, so the n-gram
       prompt-lookup drafter hits high acceptance and K>0 wins;
    2. ``churn`` — random prompts with tiny budgets (mostly admission/
       ramp-up, almost no self-history to mine): acceptance collapses
       and every drafted token is pure overhead — K=0 wins;
    3. ``shared-prefix`` — one common header plus random suffixes and
       long budgets again: high acceptance returns (plus prefix-cache
       hits on the paged layout).

    Arrivals are one continuous Poisson schedule across the phases with
    a ``phase_gap_s`` lull between them (the drain lets the next
    phase's window reflect its own traffic). Returns ``(reqs, spans)``
    where ``spans`` is ``[(name, start, end), ...]`` index ranges into
    ``reqs`` — index-based so identically-drawn workloads for different
    engines group the same way (rids are process-global)."""
    from repro.serve.request import Request

    n1, n2, n3 = (int(n) for n in phase_n)
    if prefix is None:
        prefix = rng.integers(0, vocab, size=(prefix_len,)).astype(np.int32)
    assert len(prefix) == prefix_len
    reqs, spans, t = [], [], 0.0

    def _arrive():
        nonlocal t
        t += float(rng.exponential(1.0 / rate_rps))
        return t

    start = len(reqs)
    for _ in range(n1):
        prompt = rng.integers(0, vocab, size=(rep_prompt_len,)).astype(np.int32)
        reqs.append(
            Request(prompt=prompt, max_new_tokens=int(rep_tokens),
                    arrival_time=_arrive())
        )
    spans.append(("repetitive", start, len(reqs)))
    t += float(phase_gap_s)
    start = len(reqs)
    for _ in range(n2):
        s0 = int(rng.choice(churn_prompt_lens))
        prompt = rng.integers(0, vocab, size=(s0,)).astype(np.int32)
        reqs.append(
            Request(prompt=prompt, max_new_tokens=int(churn_tokens),
                    arrival_time=_arrive())
        )
    spans.append(("churn", start, len(reqs)))
    t += float(phase_gap_s)
    start = len(reqs)
    for _ in range(n3):
        suffix = rng.integers(0, vocab, size=(suffix_len,)).astype(np.int32)
        reqs.append(
            Request(prompt=np.concatenate([prefix, suffix]),
                    max_new_tokens=int(rep_tokens), arrival_time=_arrive())
        )
    spans.append(("shared-prefix", start, len(reqs)))
    return reqs, spans


def make_shared_prefix_requests(
    n: int,
    rate_rps: float,
    *,
    vocab: int,
    prefix_len: int,
    suffix_len: int,
    max_new_tokens: int,
    rng: np.random.Generator,
    prefix=None,
):
    """n Poisson-arrival requests whose prompts share one ``prefix_len``-
    token prefix (a system prompt / few-shot header, the workload the
    prefix cache targets) followed by a per-request random
    ``suffix_len``-token tail. Draw with a same-seeded ``rng`` to get an
    identical workload across engines (requests are stateful, so each
    engine run needs its own copies); pass an explicit ``prefix`` to
    share the header across differently-seeded draws (warmup vs
    measured workloads that must hit the same cache entries)."""
    from repro.serve.request import Request

    if prefix is None:
        prefix = rng.integers(0, vocab, size=(prefix_len,)).astype(np.int32)
    assert len(prefix) == prefix_len
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    reqs = []
    for i in range(n):
        suffix = rng.integers(0, vocab, size=(suffix_len,)).astype(np.int32)
        reqs.append(
            Request(
                prompt=np.concatenate([prefix, suffix]),
                max_new_tokens=max_new_tokens,
                arrival_time=float(arrivals[i]),
            )
        )
    return reqs
