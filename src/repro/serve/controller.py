"""Online adaptive adviser: the offline pricing gates, closed into a
live control loop (DESIGN.md §9).

The paper's adviser prices alternatives with connected tools and
commits only on a predicted win; ``SpeculationAdvisorTool`` and
``KernelAdvisorTool`` reproduce that as one-shot offline gates over
pre-measured costs.  ``OnlineAdviser`` is the same price-then-decide
loop run *during* serving: every ``decision_interval`` scheduler steps
it consumes the windowed sensor vector
(``MetricsRegistry.window_summary(n)`` — observed acceptance rate p̂,
measured draft/verify/step costs, pool pressure), substitutes those
live estimates for the offline measurements, re-runs the *same* pure
pricing analytics (``core.tools.price_speculation`` /
``price_backends``), and emits a ``Decision(k, backend, admit_budget)``
for the scheduler to apply.

Why applying a decision is free: K and backend are *static shapes*
into pre-jitted step families — the verify step is one jitted function
whose ``[B, K+1]`` token block gets one trace per K, and each backend
is a dictionary entry of pre-built step functions — so after
``engine.prime()`` warms the K × backend grid, every mid-serve switch
is a cache hit (the drift benchmark pins zero retraces by trace
counter).  The only stateful transition is a drafter with its own KV
cache re-syncing on a 0→K switch (``Scheduler._set_live_k`` re-runs
``on_admit`` over the active rows).

Stability comes from hysteresis, not from trusting any one window:

* **dwell** — after a switch, the controller holds the new arm for
  ``dwell`` further decisions before it may switch again;
* **improvement threshold** — a switch must be priced at better than
  ``threshold`` relative gain *versus the currently serving arm* (the
  online baseline is the status quo, where the offline gate's baseline
  is K=0 / "reference");
* **probing** — at K=0 the acceptance rate is unobservable (nothing is
  proposed), so after ``probe_every`` consecutive decisions without a
  speculation observation the controller runs the smallest positive K
  for one interval to refresh p̂; a probe is not a committed switch and
  does not reset the dwell clock.

Every decision — applied or held — is appended to ``self.decisions``
and recorded by the scheduler on the telemetry adviser lane with its
priced inputs, the paper's audit trail, live.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

from repro.core.tools import SpecMeasurement, price_backends, price_speculation

__all__ = ["Decision", "OnlineAdviser", "PinnedController"]


@dataclass
class Decision:
    """One controller verdict: the arm to serve with until the next
    decision, plus the audit-trail fields the telemetry lane records."""

    step: int  # scheduler step the decision was made on
    k: int  # speculation depth to serve with (0 = plain decode)
    backend: str  # attention backend to serve with
    admit_budget: Optional[int] = None  # max admissions/step (None = unlimited)
    switched: bool = False  # did this decision change the committed arm?
    probe: bool = False  # temporary K>0 excursion to refresh p̂, not a commit
    predicted_gain: float = 0.0  # priced relative gain of the chosen arm
    reason: str = ""  # human-readable why
    inputs: dict = field(default_factory=dict)  # the priced sensor values

    def to_json(self) -> dict:
        return {
            "step": self.step,
            "k": self.k,
            "backend": self.backend,
            "admit_budget": self.admit_budget,
            "switched": self.switched,
            "probe": self.probe,
            "predicted_gain": round(float(self.predicted_gain), 4),
            "reason": self.reason,
            "inputs": {
                k: (round(v, 4) if isinstance(v, float) else v)
                for k, v in self.inputs.items()
            },
        }


class PinnedController:
    """A controller that always answers the same arm — the degenerate
    closed loop used by the token-identity contract (a pinned
    controller must serve bitwise-identically to the static
    configuration) and as the minimal duck-type reference: a controller
    needs only ``ks``, ``decision_interval``, ``window``, ``backends``,
    ``initial_k``, ``decisions``, ``n_switches``, ``dwell_remaining``,
    and ``decide()``."""

    def __init__(self, k: int, backend: Optional[str] = None,
                 admit_budget: Optional[int] = None, decision_interval: int = 4,
                 window: int = 16):
        self.ks = (0, int(k)) if k else (0,)
        self.backends = (backend,) if backend else None
        self.decision_interval = int(decision_interval)
        self.window = int(window)
        self.initial_k = int(k)
        self.admit_budget = admit_budget
        self.decisions: list[Decision] = []
        self.n_switches = 0
        self.dwell_remaining = 0

    def decide(self, summary: dict, *, k_live: int, backend_live: str,
               step: int) -> Decision:
        d = Decision(
            step=step, k=self.initial_k, backend=backend_live,
            admit_budget=self.admit_budget, reason="pinned",
            inputs={"acceptance_rate": summary.get("acceptance_rate", 0.0)},
        )
        self.decisions.append(d)
        return d


class OnlineAdviser:
    """Closed-loop K / backend / admission controller (module doc).

    Parameters
    ----------
    ks : candidate speculation depths (must include 0; the scheduler's
        ``SpecConfig.k`` must cover ``max(ks)`` so the admission margin
        and drafter are sized for the deepest arm).
    backends : candidate attention backends; ``None`` means "only the
        scheduler's current backend" (no backend arm).  Names are
        resolved against the ops registry by the scheduler.
    decision_interval : scheduler steps between decisions.
    window : ``window_summary(n)`` width the sensors are read over.
    dwell : decisions the controller must hold an arm after a switch.
    threshold : minimum priced relative gain vs the live arm to switch.
    probe_every : consecutive decisions without a speculation
        observation before a K=0 controller probes the smallest
        positive K for one interval (0 disables probing).
    ewma : smoothing factor for the live estimates (1.0 = trust the
        latest window entirely).
    occupancy_high / throttle_budget : when the window saw preemptions
        and mean pool occupancy is above ``occupancy_high``, the
        decision carries ``admit_budget=throttle_budget`` (admissions
        per step) to shed admission pressure; otherwise unlimited.
    initial_k : arm the scheduler starts serving with (before the first
        decision).  Defaults to 0 — start plain, let pricing raise it.
    """

    def __init__(
        self,
        *,
        ks=(0, 2, 4, 8),
        backends=None,
        decision_interval: int = 8,
        window: int = 16,
        dwell: int = 2,
        threshold: float = 0.05,
        probe_every: int = 3,
        ewma: float = 0.5,
        occupancy_high: float = 0.9,
        throttle_budget: int = 1,
        initial_k: int = 0,
    ):
        self.ks = tuple(sorted({int(k) for k in ks} | {0}))
        if any(k < 0 for k in self.ks):
            raise ValueError(f"candidate depths must be >= 0, got {ks}")
        self.backends = tuple(backends) if backends else None
        self.decision_interval = int(decision_interval)
        self.window = int(window)
        self.dwell = int(dwell)
        self.threshold = float(threshold)
        self.probe_every = int(probe_every)
        self.ewma = float(ewma)
        self.occupancy_high = float(occupancy_high)
        self.throttle_budget = int(throttle_budget)
        self.initial_k = int(initial_k)
        if self.initial_k not in self.ks:
            raise ValueError(f"initial_k={initial_k} not in ks={self.ks}")
        self.probe_k = min((k for k in self.ks if k > 0), default=0)
        self._committed_k = self.initial_k  # last non-probe depth
        # live estimates (None = no observation yet)
        self._cells: dict[tuple[str, int], float] = {}  # (backend, k) → ms/step
        self._draft: Optional[float] = None  # ms per drafted token
        self._p: Optional[float] = None  # EWMA acceptance rate p̂
        self._stale = 0  # decisions since the last speculation observation
        self.dwell_remaining = 0
        self.decisions: list[Decision] = []
        self.n_switches = 0

    # -- seeding -------------------------------------------------------
    def seed_costs(self, cells, draft_ms_per_token: Optional[float] = None) -> None:
        """Prime the cost cells from ``engine.prime()``'s measured
        K × backend grid (accepts the prime() result dict or a raw
        ``{backend: {k: ms}}`` mapping), so the very first decision
        prices real numbers instead of flying blind."""
        if isinstance(cells, dict) and "cells" in cells:
            cells = cells["cells"]
        for backend, by_k in cells.items():
            for k, ms in by_k.items():
                self._cells[(str(backend), int(k))] = float(ms)
        if draft_ms_per_token is not None:
            self._draft = float(draft_ms_per_token)

    # -- sensing -------------------------------------------------------
    def _ewma_in(self, old: Optional[float], new: float) -> float:
        return new if old is None else (1.0 - self.ewma) * old + self.ewma * new

    def _observe(self, summary: dict, k_live: int, backend_live: str) -> None:
        proposed = summary.get("proposed", 0.0)
        if proposed > 0:
            self._stale = 0
            self._p = self._ewma_in(self._p, float(summary["acceptance_rate"]))
            if k_live > 0:
                draft = summary.get("p50_draft_ms", 0.0)
                if draft > 0:
                    self._draft = self._ewma_in(self._draft, draft / k_live)
                verify = summary.get("p50_verify_ms", 0.0)
                if verify > 0:
                    key = (backend_live, k_live)
                    self._cells[key] = self._ewma_in(self._cells.get(key), verify)
        else:
            self._stale += 1
            # plain decode: the step cost IS the K=0 cell for this backend
            step = summary.get("step_cost_ms", 0.0)
            if k_live == 0 and step > 0:
                key = (backend_live, 0)
                self._cells[key] = self._ewma_in(self._cells.get(key), step)

    def _verify_cells(self, backend: str) -> dict[int, float]:
        return {
            k: ms for (b, k), ms in self._cells.items()
            if b == backend and (k == 0 or k in self.ks)
        }

    # -- deciding ------------------------------------------------------
    def decide(self, summary: dict, *, k_live: int, backend_live: str,
               step: int) -> Decision:
        """Price the candidate arms against the live window estimates
        and return the arm to serve with (possibly unchanged).  Always
        returns a Decision — held decisions are part of the audit trail."""
        self._observe(summary, k_live, backend_live)
        dwell_ok = self.dwell_remaining <= 0
        if self.dwell_remaining > 0:
            self.dwell_remaining -= 1
        new_k, new_backend, probe = k_live, backend_live, False
        gain, reasons = 0.0, []

        # speculation arm — the SpeculationAdvisorTool pricing with live
        # estimates, gained against the *currently serving* depth
        cells = self._verify_cells(backend_live)
        spec_ks = [k for k in self.ks if k > 0]
        if spec_ks and 0 in cells:
            m = SpecMeasurement(
                draft_ms_per_token=self._draft if self._draft is not None else 0.0,
                verify_ms=cells,
                acceptance_rate=self._p if self._p is not None else 0.0,
            )
            k_target, _cost, _g0, costs = price_speculation(m, self.ks, threshold=0.0)
            # hysteresis baseline: the committed arm, not a transient
            # probe — a probe must clear the gain gate to stick
            ref = self._committed_k
            cur = costs.get(ref, m.verify_cost(ref))
            tgt = costs[k_target]
            k_gain = (cur / tgt - 1.0) if tgt > 0 else 0.0
            observed = self._p is not None and self._stale < max(1, self.probe_every)
            if k_target != ref and dwell_ok and k_gain > self.threshold and (
                observed or k_target == 0
            ):
                new_k, gain = k_target, k_gain
                reasons.append(f"k {ref}→{k_target} ({k_gain:+.1%})")
            elif k_live != ref:
                # probe interval over without a priced win: revert
                new_k = ref
                reasons.append(f"probe over, k→{ref}")
        if (
            new_k == k_live
            and k_live == 0
            and self.probe_k > 0
            and self.probe_every > 0
            and (self._p is None or self._stale >= self.probe_every)
        ):
            # acceptance is unobservable at K=0: run the smallest
            # positive depth for one interval to refresh p̂
            new_k, probe = self.probe_k, True
            reasons.append(f"probe k={self.probe_k} (p̂ stale)")

        # backend arm — KernelAdvisorTool pricing over this depth's
        # measured cells, baselined on the live backend
        if self.backends and len(self.backends) > 1 and not probe and dwell_ok:
            by_backend = {
                b: self._cells[(b, new_k)]
                for b in self.backends
                if (b, new_k) in self._cells
            }
            if backend_live in by_backend and len(by_backend) > 1:
                b_target, _ms, b_gain = price_backends(
                    by_backend, self.threshold, baseline=backend_live
                )
                if b_target != backend_live:
                    new_backend = b_target
                    gain = max(gain, b_gain)
                    reasons.append(
                        f"backend {backend_live}→{b_target} ({b_gain:+.1%})"
                    )

        # a probe is an excursion, not a commit: switches are counted
        # against the last *committed* depth, so a probe that pricing
        # confirms (the arm stays at probe_k) still registers as one
        switched = not probe and (
            new_k != self._committed_k or new_backend != backend_live
        )
        if not probe:
            self._committed_k = new_k
        if switched:
            self.dwell_remaining = self.dwell
            self.n_switches += 1

        d = Decision(
            step=step,
            k=new_k,
            backend=new_backend,
            admit_budget=self._admission(summary),
            switched=switched,
            probe=probe,
            predicted_gain=float(gain),
            reason="; ".join(reasons) or "hold",
            inputs={
                "acceptance_rate": float(summary.get("acceptance_rate", 0.0)),
                "p_hat": float(self._p) if self._p is not None else None,
                "draft_ms_per_token": (
                    float(self._draft) if self._draft is not None else None
                ),
                "step_cost_ms": float(summary.get("step_cost_ms", 0.0)),
                "pool_occupancy": float(summary.get("pool_occupancy", 0.0)),
                "queue_depth": float(summary.get("queue_depth", 0.0)),
                "preemptions": float(summary.get("preemptions", 0.0)),
                "window": summary.get("window", 0),
            },
        )
        self.decisions.append(d)
        return d

    def _admission(self, summary: dict) -> Optional[int]:
        if (
            summary.get("preemptions", 0.0) > 0
            and summary.get("pool_occupancy", 0.0) >= self.occupancy_high
        ):
            return max(1, self.throttle_budget)
        return None

    # -- exposition ----------------------------------------------------
    def audit_trail(self) -> list[dict]:
        """The full decision history, JSON-ready (the drift benchmark
        writes this as the CI artifact)."""
        return [d.to_json() for d in self.decisions]

    def summary(self) -> dict[str, Any]:
        last = self.decisions[-1] if self.decisions else None
        return {
            "decisions": len(self.decisions),
            "switches": self.n_switches,
            "probes": sum(d.probe for d in self.decisions),
            "k": last.k if last else self.initial_k,
            "backend": last.backend if last else None,
            "dwell_remaining": self.dwell_remaining,
            "p_hat": self._p,
            "draft_ms_per_token": self._draft,
        }
