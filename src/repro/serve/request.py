"""Request lifecycle for the continuous-batching serving core.

A ``Request`` is the schedulable unit the paper's task-queue analogy
maps onto at serving scale: where Relic splits a hotspot into microtasks
cheap enough to co-schedule, the serving layer splits traffic into
requests cheap enough to admit and retire individually (DESIGN.md §3).
States move queued → prefill → decode → finished, with a preempted
detour (decode → preempted → prefill) when block pressure evicts a
low-priority row; the scheduler owns every transition. Latency
accounting is per-request — TTFT (arrival to first token, including
queueing), queue wait (arrival to first admission, the scheduler-owned
part of TTFT), TPOT (decode time per subsequent token), and end-to-end
— aggregated across a run by ``ServeStats``.

All times are seconds on the scheduler's run clock (0 = run start), so
``arrival_time`` doubles as the open-loop load generator's injection
schedule.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.serve.telemetry import MetricsRegistry, quantile

QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
PREEMPTED = "preempted"
FINISHED = "finished"

_RID = itertools.count()


@dataclass(eq=False)  # identity semantics: requests are mutable and unique
class Request:
    """One generation request: a prompt, a token budget, an arrival time."""

    prompt: Any  # [S0] int token ids
    max_new_tokens: int
    arrival_time: float = 0.0  # seconds from run start (open-loop schedule)
    eos_id: Optional[int] = None  # early finish on this token
    patch_embeds: Any = None  # [P, D] VLM frontend embeddings
    priority: int = 0  # higher = more important (strict-priority admission)
    rid: int = field(default_factory=lambda: next(_RID))

    # lifecycle — owned by the scheduler
    state: str = QUEUED
    slot: Optional[int] = None  # slot index (slotted) / decode row (paged)
    prefix_hit: int = 0  # prompt tokens served from the prefix cache
    tokens: list = field(default_factory=list)
    t_admit: Optional[float] = None  # prefill started (slot allocated)
    t_first: Optional[float] = None  # first token available
    t_finish: Optional[float] = None
    # preempt/resume — owned by the scheduler
    preemptions: int = 0  # times this request was evicted mid-decode
    sample_key: Any = None  # per-row PRNG key saved across preemption
    t_first_admit: Optional[float] = None  # first admission (queue wait ends)

    @property
    def finished(self) -> bool:
        return self.state == FINISHED

    def output(self) -> np.ndarray:
        return np.asarray(self.tokens, np.int32)

    # ------------------------------------------------------------------
    # latency accounting
    @property
    def ttft_ms(self) -> Optional[float]:
        """Arrival → first token, queueing included."""
        if self.t_first is None:
            return None
        return (self.t_first - self.arrival_time) * 1e3

    @property
    def queue_wait_ms(self) -> Optional[float]:
        """Arrival → first admission: the scheduler-owned slice of TTFT
        (load + priority), as opposed to prefill compute."""
        if self.t_first_admit is None:
            return None
        return (self.t_first_admit - self.arrival_time) * 1e3

    @property
    def service_ttft_ms(self) -> Optional[float]:
        """First admission → first token: TTFT with queueing split out."""
        if self.t_first is None or self.t_first_admit is None:
            return None
        return (self.t_first - self.t_first_admit) * 1e3

    @property
    def e2e_ms(self) -> Optional[float]:
        if self.t_finish is None:
            return None
        return (self.t_finish - self.arrival_time) * 1e3

    @property
    def tpot_ms(self) -> Optional[float]:
        """Decode time per token after the first (None with <2 tokens)."""
        if self.t_finish is None or len(self.tokens) < 2:
            return None
        return (self.t_finish - self.t_first) / (len(self.tokens) - 1) * 1e3


class ServeStats:
    """Per-run latency aggregates: decode-step wall-clock plus the
    per-request TTFT/TPOT/e2e series recorded as requests retire.

    Backed by a ``telemetry.MetricsRegistry`` (DESIGN.md §8): every
    latency series is a registry ``Series`` (a real list — append call
    sites are unchanged) and every scalar counter is a registry
    ``Counter`` exposed through int properties, so the same numbers
    that feed ``serving_summary()`` are also visible to
    ``registry.window_summary(n)`` as windowed signals (per-window
    acceptance rate, prefix hit rate, preemption rate, …) without a
    second bookkeeping path.  The ``serving_summary()`` schema is
    unchanged by the refactor (pinned by tests/test_telemetry.py)."""

    # attribute → registry metric. Series are unbounded sample lists;
    # counters are cumulative scalars snapshotted per scheduler tick.
    _SERIES = {
        "step_ms": "serve.step_ms",
        "ttft_ms": "serve.ttft_ms",
        "tpot_ms": "serve.tpot_ms",
        "e2e_ms": "serve.e2e_ms",
        # queue-wait / service split of TTFT (queue_wait + service = ttft)
        "queue_wait_ms": "serve.queue_wait_ms",
        "service_ttft_ms": "serve.service_ttft_ms",
        # speculative-decode per-step latency split (draft vs verify)
        "draft_ms": "serve.draft_ms",
        "verify_ms": "serve.verify_ms",
    }
    _COUNTERS = {
        # prefix-cache accounting (paged layout; zero on the slotted path)
        "prompt_tokens": "serve.prompt_tokens",
        "prefix_hit_tokens": "serve.prefix_hit_tokens",
        "n_prefix_hits": "serve.n_prefix_hits",
        # preemption accounting (priority scheduling under block pressure)
        "n_preemptions": "serve.preemptions",
        "recomputed_tokens": "serve.recomputed_tokens",
        "rejected_submissions": "serve.rejected_submissions",
        # speculative-decode proposed/accepted behind the acceptance rate
        "spec_k": "serve.spec_k",
        "spec_steps": "serve.spec_steps",
        "spec_proposed": "serve.spec_proposed",
        "spec_accepted": "serve.spec_accepted",
    }

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        for attr, metric in self._SERIES.items():
            setattr(self, attr, self.registry.series(metric))
        self._counters = {
            attr: self.registry.counter(metric)
            for attr, metric in self._COUNTERS.items()
        }
        # online-adviser state for serving_summary(): populated by the
        # scheduler per decision when a controller runs, None otherwise
        # (the "controller" key appears only when a controller ran, so
        # the golden summary schema is unchanged for plain runs)
        self.controller_info: Optional[dict] = None

    def reset(self) -> None:
        """Start a run from clean series — percentiles never mix runs.
        Resets the whole registry in place (series/counters/gauges and
        tick rings), so cached metric handles stay valid."""
        self.registry.reset()
        self.controller_info = None

    def record(self, req: Request) -> None:
        """Fold a finished request's latencies into the run series."""
        if req.ttft_ms is not None:
            self.ttft_ms.append(req.ttft_ms)
        if req.queue_wait_ms is not None:
            self.queue_wait_ms.append(req.queue_wait_ms)
        if req.service_ttft_ms is not None:
            self.service_ttft_ms.append(req.service_ttft_ms)
        if req.tpot_ms is not None:
            self.tpot_ms.append(req.tpot_ms)
        if req.e2e_ms is not None:
            self.e2e_ms.append(req.e2e_ms)
        self.prompt_tokens += int(np.asarray(req.prompt).shape[0])
        self.prefix_hit_tokens += req.prefix_hit
        self.n_prefix_hits += bool(req.prefix_hit)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens served from the prefix cache."""
        return self.prefix_hit_tokens / self.prompt_tokens if self.prompt_tokens else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the verify accepted."""
        return self.spec_accepted / self.spec_proposed if self.spec_proposed else 0.0

    def percentile(self, p, series: str = "step_ms") -> float:
        """Linear-interpolated percentile over a latency series —
        matches ``numpy.percentile``'s default method (unit-tested in
        tests/test_telemetry.py), so p99 over a short series
        interpolates between ranks instead of collapsing to the max."""
        vals = getattr(self, series)
        return quantile(vals, p) if vals else 0.0

    def summary(self) -> str:
        s = (
            f"steps={len(self.step_ms)} p50={self.percentile(50):.2f}ms "
            f"p99={self.percentile(99):.2f}ms"
        )
        if self.ttft_ms:
            s += (
                f" | requests={len(self.ttft_ms)}"
                f" ttft_p50={self.percentile(50, 'ttft_ms'):.2f}ms"
                f" ttft_p99={self.percentile(99, 'ttft_ms'):.2f}ms"
            )
        return s

    def serving_summary(self) -> dict:
        """Machine-readable serving latencies (BENCH_aira.json section).

        A run where zero requests finished returns an *explicit* empty
        summary — ``empty=True`` with ``None`` for every per-request
        percentile — instead of letting empty series masquerade as
        0 ms latencies (or propagate NaN through downstream ratios).
        Step timings survive either way: steps are measured per decode,
        not per retirement."""
        out = {
            "n_requests": len(self.ttft_ms),
            "n_steps": len(self.step_ms),
            "empty": not self.ttft_ms,
            "p50_step_ms": self.percentile(50) if self.step_ms else None,
            "p99_step_ms": self.percentile(99) if self.step_ms else None,
            "prompt_tokens": self.prompt_tokens,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": self.prefix_hit_rate,
            "n_prefix_hits": self.n_prefix_hits,
            "preemptions": self.n_preemptions,
            "recomputed_tokens": self.recomputed_tokens,
            "rejected_submissions": self.rejected_submissions,
        }
        if self.ttft_ms:
            qw, sv = self.queue_wait_ms, self.service_ttft_ms
            out.update(
                p50_ttft_ms=self.percentile(50, "ttft_ms"),
                p99_ttft_ms=self.percentile(99, "ttft_ms"),
                p50_queue_wait_ms=self.percentile(50, "queue_wait_ms") if qw else None,
                p99_queue_wait_ms=self.percentile(99, "queue_wait_ms") if qw else None,
                p50_service_ttft_ms=self.percentile(50, "service_ttft_ms") if sv else None,
                p99_service_ttft_ms=self.percentile(99, "service_ttft_ms") if sv else None,
                p50_tpot_ms=self.percentile(50, "tpot_ms") if self.tpot_ms else None,
                p99_tpot_ms=self.percentile(99, "tpot_ms") if self.tpot_ms else None,
                p50_e2e_ms=self.percentile(50, "e2e_ms"),
                p99_e2e_ms=self.percentile(99, "e2e_ms"),
            )
        else:
            out.update(
                p50_ttft_ms=None, p99_ttft_ms=None,
                p50_queue_wait_ms=None, p99_queue_wait_ms=None,
                p50_service_ttft_ms=None, p99_service_ttft_ms=None,
                p50_tpot_ms=None, p99_tpot_ms=None,
                p50_e2e_ms=None, p99_e2e_ms=None,
            )
        if self.spec_steps:
            out["speculative"] = {
                "k": self.spec_k,
                "acceptance_rate": self.acceptance_rate,
                "proposed": self.spec_proposed,
                "accepted": self.spec_accepted,
                "spec_steps": self.spec_steps,
                "p50_draft_ms": self.percentile(50, "draft_ms"),
                "p50_verify_ms": self.percentile(50, "verify_ms"),
            }
        if self.controller_info:
            out["controller"] = dict(self.controller_info)
        return out


def _counter_property(attr: str) -> property:
    # int get / set pair over the backing registry Counter, so existing
    # `stats.prompt_tokens += n` call sites work unchanged
    def fget(self):
        return int(self._counters[attr].value)

    def fset(self, v):
        self._counters[attr].set(float(v))

    return property(fget, fset)


for _attr in ServeStats._COUNTERS:
    setattr(ServeStats, _attr, _counter_property(_attr))
del _attr
