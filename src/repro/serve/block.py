"""Block pool and prefix trie for the paged KV cache.

The paper's thesis is that latency-critical code lives or dies by its
memory behavior: Relic microtasks only pay off once cache misses are
under control. At serving scale the analogous resource is KV-cache
memory — a slot-granular pool reserves ``max_seq`` tokens per request
(worst-case footprint) and recomputes identical prompt prefixes per
request. This module provides the two pieces that fix both:

* ``BlockAllocator`` — a fixed pool of fixed-size cache blocks with
  per-block refcounts. Blocks are *live* (refcount > 0), *free*, or
  *parked*: a parked block has no referents but still holds reusable
  prefix data, sitting in an LRU bench from which ``alloc`` evicts when
  the free list runs dry. Evicting a referenced block is impossible by
  construction (the property tests pin this).

* ``PrefixCache`` — a trie over block-granular token keys. Each node is
  one immutable, fully-written block of some request's prompt;
  ``match`` walks the longest chain of cached blocks equal to a new
  prompt's prefix so the scheduler can alias them (refcount++) instead
  of recomputing, and ``insert`` registers a new prompt's full blocks
  for future requests. Dropping a block drops its whole subtree — a
  child block's data is only addressable through its parent chain.

Shared blocks are immutable: the scheduler never hands out a partially
filled ("divergence") block for sharing, so decode writes always land
in blocks owned by exactly one request — copy-on-write realized as
*copy-on-join* (a joining request recomputes its divergence block
rather than mutating a shared one). See DESIGN.md §3.
"""
from __future__ import annotations

import bisect
from typing import Callable, Optional


class BlockAllocator:
    """Refcounted fixed pool of KV-cache blocks with LRU eviction of
    parked (unreferenced but data-bearing) blocks."""

    def __init__(
        self,
        num_blocks: int,
        on_evict: Optional[Callable] = None,
        is_leaf: Optional[Callable] = None,
        metrics=None,
    ):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        self.num_blocks = int(num_blocks)
        self.refcount = [0] * self.num_blocks
        self._free: list[int] = list(range(self.num_blocks))  # ascending
        self._parked: dict[int, int] = {}  # block → park tick (LRU order)
        self._tick = 0
        # on_evict(block) → iterable of *descendant* parked blocks that
        # become unreachable and must be evicted too (set by PrefixCache)
        self.on_evict = on_evict
        # is_leaf(block) → True when evicting the block cannot cascade;
        # alloc() prefers such victims so reclaiming ONE block never
        # destroys a whole cached prefix chain (prefix hit rates degrade
        # from the divergence tails inward, not root-first)
        self.is_leaf = is_leaf
        # optional telemetry.MetricsRegistry (DESIGN.md §8): alloc /
        # share / park / evict rates. Counter handles are cached here so
        # the instrumented path is one predictable branch + inc; with
        # metrics=None (telemetry off) nothing is recorded.
        self._m_alloc = metrics.counter("pool.alloc") if metrics else None
        self._m_share = metrics.counter("pool.share") if metrics else None
        self._m_park = metrics.counter("pool.park") if metrics else None
        self._m_evict = metrics.counter("pool.evict") if metrics else None

    # ------------------------------------------------------------------
    # occupancy
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_parked(self) -> int:
        return len(self._parked)

    @property
    def n_live(self) -> int:
        return self.num_blocks - self.n_free - self.n_parked

    @property
    def n_available(self) -> int:
        """Blocks obtainable by ``alloc`` right now: free + evictable."""
        return self.n_free + self.n_parked

    def is_parked(self, block: int) -> bool:
        return block in self._parked

    def parked_lru(self) -> list[int]:
        """Parked blocks, least-recently-parked first (eviction order)."""
        return sorted(self._parked, key=self._parked.get)

    # ------------------------------------------------------------------
    # lifecycle
    def alloc(self) -> int:
        """Claim a fresh block (refcount 1): lowest free block, else evict
        the least-recently-parked *leaf* block (oldest parked overall
        when no leaf oracle is installed) and reuse it."""
        if not self._free and self._parked:
            lru = self.parked_lru()
            victim = next(
                (b for b in lru if self.is_leaf is None or self.is_leaf(b)), lru[0]
            )
            self.evict(victim)
        if not self._free:
            raise RuntimeError(
                f"no free KV block ({self.n_live} live, 0 parked, "
                f"pool={self.num_blocks})"
            )
        block = self._free.pop(0)
        self.refcount[block] = 1
        if self._m_alloc is not None:
            self._m_alloc.inc()
        return block

    def share(self, block: int) -> None:
        """Add a referent to ``block``. Reactivates a parked block (a
        prefix hit on a retired request's prompt); sharing a free block
        is a bug."""
        self._check_range(block)
        if self.refcount[block] == 0:
            if block not in self._parked:
                raise RuntimeError(f"sharing free block {block}")
            del self._parked[block]
        self.refcount[block] += 1
        if self._m_share is not None:
            self._m_share.inc()

    def free(self, block: int, park: bool = False) -> None:
        """Drop one referent. At refcount 0 the block returns to the free
        list, or — with ``park=True`` (it is registered in a prefix
        trie) — to the LRU bench, evictable but still reusable."""
        self._check_range(block)
        if self.refcount[block] <= 0:
            raise RuntimeError(f"double free of block {block}")
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            if park:
                self._tick += 1
                self._parked[block] = self._tick
                if self._m_park is not None:
                    self._m_park.inc()
            else:
                bisect.insort(self._free, block)

    def evict(self, block: int) -> None:
        """Reclaim a parked block (and any parked descendants its trie
        drop reports). Evicting a referenced block is impossible."""
        self._check_range(block)
        if self.refcount[block] > 0:
            raise RuntimeError(
                f"evicting block {block} with refcount {self.refcount[block]}"
            )
        if block not in self._parked:
            raise RuntimeError(f"evicting block {block} that is not parked")
        cascade = [block]
        if self.on_evict is not None:
            cascade += [b for b in self.on_evict(block) if b != block]
        for b in cascade:
            if b in self._parked:  # descendants are parked by closure
                del self._parked[b]
                bisect.insort(self._free, b)
                if self._m_evict is not None:
                    self._m_evict.inc()

    def _check_range(self, block: int) -> None:
        if not 0 <= block < self.num_blocks:
            raise IndexError(f"block {block} out of range")

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """{live, parked, free} partition the pool; refcounts are never
        negative; parked blocks are exactly the refcount-0 non-free ones;
        the free list is sorted and duplicate-free."""
        free = set(self._free)
        parked = set(self._parked)
        live = {b for b in range(self.num_blocks) if self.refcount[b] > 0}
        assert len(self._free) == len(free), "duplicate in free list"
        assert self._free == sorted(self._free), "free list unsorted"
        assert all(r >= 0 for r in self.refcount), "negative refcount"
        assert not (free & parked), "block both free and parked"
        assert not (free & live), "block both free and referenced"
        assert not (parked & live), "block both parked and referenced"
        assert free | parked | live == set(range(self.num_blocks)), "block leaked"


class _TrieNode:
    __slots__ = ("key", "block", "parent", "children")

    def __init__(self, key, block, parent):
        self.key = key  # tuple of this block's tokens
        self.block = block  # block id holding this node's KV rows
        self.parent = parent
        self.children: dict[tuple, "_TrieNode"] = {}


class PrefixCache:
    """Trie of immutable prompt blocks, keyed block-by-block on tokens."""

    def __init__(self, block_size: int):
        self.block_size = int(block_size)
        self._root = _TrieNode(None, None, None)
        self._by_block: dict[int, _TrieNode] = {}

    @property
    def n_blocks(self) -> int:
        return len(self._by_block)

    def registered(self, block: int) -> bool:
        return block in self._by_block

    def is_leaf(self, block: int) -> bool:
        """True when evicting ``block`` cannot cascade: it has no trie
        children (an unregistered block trivially qualifies). A parked
        node's children are themselves parked (refcounts are monotone
        down a chain), so evicting leaves first shrinks cached chains
        from their divergence tails inward."""
        node = self._by_block.get(block)
        return node is None or not node.children

    # ------------------------------------------------------------------
    def _keys(self, tokens, n_blocks: int):
        bs = self.block_size
        return [tuple(tokens[j * bs : (j + 1) * bs]) for j in range(n_blocks)]

    def match(self, tokens) -> list[int]:
        """Block ids of the longest cached chain equal to a prefix of
        ``tokens``. Capped at ``(len(tokens) - 1) // block_size`` blocks:
        at least one suffix token must remain to prefill, so the request
        has logits to sample its first token from."""
        out: list[int] = []
        node = self._root
        for key in self._keys(tokens, (len(tokens) - 1) // self.block_size):
            child = node.children.get(key)
            if child is None:
                break
            out.append(child.block)
            node = child
        return out

    def insert(self, tokens, block_ids) -> None:
        """Register a prompt's immutable blocks: ``block_ids[j]`` holds
        tokens ``[j*bs, (j+1)*bs)``. Only blocks the request will never
        write into may be passed (full blocks strictly before the decode
        write position). Chains already present keep their first
        registration — a duplicate block stays private to its request."""
        node = self._root
        for key, block in zip(self._keys(tokens, len(block_ids)), block_ids):
            child = node.children.get(key)
            if child is None:
                if block in self._by_block:
                    raise RuntimeError(f"block {block} registered twice")
                child = _TrieNode(key, block, node)
                node.children[key] = child
                self._by_block[block] = child
            node = child

    def drop_block(self, block: int) -> list[int]:
        """Remove ``block``'s node and its whole subtree (children are
        unreachable without their parent chain). Returns the descendant
        block ids so the allocator can evict them in cascade."""
        node = self._by_block.get(block)
        if node is None:
            return []
        del node.parent.children[node.key]
        dropped: list[int] = []
        stack = [node]
        while stack:
            n = stack.pop()
            del self._by_block[n.block]
            if n.block != block:
                dropped.append(n.block)
            stack.extend(n.children.values())
        return dropped
