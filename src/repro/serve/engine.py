"""Batched latency-critical serving driver.

The paper's subject is latency-critical request processing; at LM scale
that is the decode loop. The engine runs continuous batched decoding with
per-request latency accounting (p50/p99), greedy or temperature sampling,
and exposes ``serve_step`` — the function the multi-pod dry-run lowers
for the decode_* / long_* shapes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ServeStats:
    step_ms: list = field(default_factory=list)

    def percentile(self, p):
        return float(np.percentile(np.asarray(self.step_ms), p)) if self.step_ms else 0.0

    def summary(self) -> str:
        return (
            f"steps={len(self.step_ms)} p50={self.percentile(50):.2f}ms "
            f"p99={self.percentile(99):.2f}ms"
        )


class ServingEngine:
    def __init__(self, model, params, *, max_seq: int, temperature: float = 0.0):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.temperature = temperature
        self._prefill = jax.jit(lambda p, t, **kw: model.prefill(p, t, max_seq, **kw))
        self._decode = jax.jit(model.decode_step)
        self.stats = ServeStats()

    # ------------------------------------------------------------------
    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.temperature, axis=-1)

    def generate(self, prompts: jax.Array, n_steps: int, *, seed: int = 0, patch_embeds=None):
        """prompts [B, S0] → generated tokens [B, n_steps]."""
        kw = {}
        if patch_embeds is not None:
            kw["patch_embeds"] = patch_embeds
        logits, cache = self._prefill(self.params, prompts, **kw)
        key = jax.random.key(seed)
        out = []
        tok = self._sample(logits, key)
        for i in range(n_steps):
            out.append(tok)
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, cache, tok[:, None])
            logits.block_until_ready()
            self.stats.step_ms.append((time.perf_counter() - t0) * 1e3)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return jnp.stack(out, axis=1)
