"""Batched latency-critical serving driver.

The paper's subject is latency-critical request processing; at LM scale
that is the decode loop. The engine runs continuous batched decoding with
per-request latency accounting (p50/p99), greedy or temperature sampling,
and exposes ``serve_step`` — the function the multi-pod dry-run lowers
for the decode_* / long_* shapes.

Serving is also an *advisable workload*: ``decode_region`` exposes one
decode step as an Aira ``Region`` whose work items are the concurrent
requests (per-request KV-cache slices are disjoint by construction, so
the dynamic-dependence stage clears), and ``set_decode_plan`` accepts
the resulting ``RegionPlan`` so the decode step runs through the plan's
compiled co-scheduled restructuring (DESIGN.md §1).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ServeStats:
    step_ms: list = field(default_factory=list)

    def percentile(self, p):
        return float(np.percentile(np.asarray(self.step_ms), p)) if self.step_ms else 0.0

    def summary(self) -> str:
        return (
            f"steps={len(self.step_ms)} p50={self.percentile(50):.2f}ms "
            f"p99={self.percentile(99):.2f}ms"
        )


class ServingEngine:
    def __init__(
        self,
        model,
        params,
        *,
        max_seq: int,
        temperature: float = 0.0,
        decode_plan=None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.temperature = temperature
        self._prefill = jax.jit(lambda p, t, **kw: model.prefill(p, t, max_seq, **kw))
        self._decode = jax.jit(model.decode_step)
        self._decode_plan = None
        self._plan_step = None
        self.stats = ServeStats()
        if decode_plan is not None:
            self.set_decode_plan(decode_plan)

    # ------------------------------------------------------------------
    # the decode step as an advisable region (requests = work items)

    def _decode_cache_spec(self, cache):
        """(treedef, per-leaf batch-axis index) of the decode cache."""
        leaves, treedef = jax.tree.flatten(cache)
        logical = jax.tree.flatten(
            self.model.cache_axes(cache), is_leaf=lambda x: isinstance(x, tuple)
        )[0]
        axes = tuple(t.index("batch") if "batch" in t else 0 for t in logical)
        assert len(axes) == len(leaves)
        return treedef, axes

    def _per_request_decode(self, treedef, axes):
        """Per-item fn over (token, batchless cache leaves): one request's
        decode step — what relic_pfor co-schedules across requests."""
        model, params = self.model, self.params

        def fn(item):
            tok, leaves = item
            cache = jax.tree.unflatten(
                treedef, [jnp.expand_dims(l, ax) for l, ax in zip(leaves, axes)]
            )
            logits, new_cache = model.decode_step(params, cache, tok.reshape(1, 1))
            new_leaves = [
                jnp.moveaxis(l, ax, 0)[0]
                for l, ax in zip(jax.tree.leaves(new_cache), axes)
            ]
            return logits[0], new_leaves

        return fn

    def _decode_items(self, cache, tok, axes):
        leaves = jax.tree.leaves(cache)
        return (tok, [jnp.moveaxis(l, ax, 0) for l, ax in zip(leaves, axes)])

    def decode_region(
        self,
        prompts: jax.Array,
        *,
        name: str = "serve-decode",
        task_flops: Optional[float] = None,
        task_bytes: Optional[float] = None,
        task_chain: int = 0,
        force: bool = False,
    ):
        """Expose one decode step as an Aira ``Region``.

        Items are the batch of concurrent requests. The attached dynamic
        trace records each request touching only its own cache slice
        (disjoint by construction), so the dependence stages clear and
        the overlap gate decides. Default napkin cost: weight-streaming
        decode — 2·n_params FLOPs and n_params·4 bytes per request-token
        (batched decode is bandwidth-bound, which is exactly why the
        gate usually says no and latency-critical deployments ``force``).
        """
        from repro.core.adviser import Region
        from repro.core.deps import MemoryTrace

        logits, cache = self._prefill(self.params, prompts)
        tok = self._sample(logits, jax.random.key(0))
        treedef, axes = self._decode_cache_spec(cache)
        items = self._decode_items(cache, tok, axes)
        n_params = sum(l.size for l in jax.tree.leaves(self.params))
        batch = int(tok.shape[0])
        trace = MemoryTrace(
            reads=[[i] for i in range(batch)], writes=[[i] for i in range(batch)]
        )
        return Region(
            name=name,
            fn=self._per_request_decode(treedef, axes),
            items=items,
            task_flops=2.0 * n_params if task_flops is None else task_flops,
            task_bytes=4.0 * n_params if task_bytes is None else task_bytes,
            task_chain=task_chain,
            vector=False,
            trace=trace,
            force=force,
        )

    def set_decode_plan(self, plan) -> None:
        """Route the decode step through an accepted ``RegionPlan`` (as
        produced by advising ``decode_region`` — stack combine)."""
        if plan is not None and plan.key.combine != "stack":
            raise ValueError("decode plan must preserve per-request order (combine='stack')")
        self._decode_plan = plan
        self._plan_step = None  # rebuilt lazily against the cache spec

    def _plan_decode(self, cache, tok):
        if self._plan_step is None:
            # the cache spec is invariant across steps: derive it once and
            # fold the batch-axis shuffling into one jitted step so the
            # per-token path stays a single dispatch
            treedef, axes = self._decode_cache_spec(cache)
            plan = self._decode_plan

            def step(cache, tok):
                leaves = jax.tree.leaves(cache)
                items = (tok, [jnp.moveaxis(l, ax, 0) for l, ax in zip(leaves, axes)])
                logits, new_leaves = plan.execute(items)
                new_cache = jax.tree.unflatten(
                    treedef,
                    [jnp.moveaxis(l, 0, ax) for l, ax in zip(new_leaves, axes)],
                )
                return logits, new_cache

            self._plan_step = jax.jit(step)
        return self._plan_step(cache, tok)

    # ------------------------------------------------------------------
    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.temperature, axis=-1)

    def generate(self, prompts: jax.Array, n_steps: int, *, seed: int = 0, patch_embeds=None):
        """prompts [B, S0] → generated tokens [B, n_steps]."""
        kw = {}
        if patch_embeds is not None:
            kw["patch_embeds"] = patch_embeds
        logits, cache = self._prefill(self.params, prompts, **kw)
        key = jax.random.key(seed)
        out = []
        tok = self._sample(logits, key)
        for i in range(n_steps):
            out.append(tok)
            t0 = time.perf_counter()
            if self._decode_plan is not None:
                logits, cache = self._plan_decode(cache, tok)
            else:
                logits, cache = self._decode(self.params, cache, tok[:, None])
            logits.block_until_ready()
            self.stats.step_ms.append((time.perf_counter() - t0) * 1e3)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return jnp.stack(out, axis=1)
