"""Continuous-batching serving engine (facade over the serving core).

The paper's subject is latency-critical request processing; at LM scale
that is the decode loop. PR 1 made the decode step *advisable* (one
``Region`` whose work items are concurrent requests); this layer makes
it *servable*: requests are admitted, decoded, and retired individually
(DESIGN.md §3), with the decode step still routable through an accepted
``RegionPlan``.

  request.py    Request lifecycle (queued → prefill → decode → finished)
                + per-request TTFT/TPOT/e2e + prefix-hit accounting
                (``ServeStats``)
  block.py      ``BlockAllocator`` (refcounted block pool, LRU eviction)
                + ``PrefixCache`` (trie of immutable prompt blocks)
  kv_cache.py   ``SlotKVCache`` — fixed pool of ``max_batch`` cache
                slots; allocate on admit, free on finish/EOS.
                ``PagedKVCache`` — block-granular cache memory with
                shared-prefix reuse (``kv_layout="paged"``)
  scheduler.py  ``Scheduler`` — per step: admit into free rows (charged
                in slots or blocks; prefix hits prefill the suffix
                only), one batched decode over the full pool (masked
                plan execution when a plan is set; block-table
                gather/scatter when paged — live-count, table, and
                length changes never retrace)
  speculative.py ``DraftSource`` streams (n-gram prompt-lookup / small
                draft model), ``SpecConfig``, and ``advise_depth`` —
                probe-measure a workload, let the
                ``SpeculationAdvisorTool`` pick K (DESIGN.md §3.2)
  engine.py     this facade: ``serve()`` is the open-loop entry,
                ``generate()`` the fixed-batch compatibility wrapper,
                ``decode_region()``/``set_decode_plan()`` the PR 1
                advisory contract, unchanged. ``kv_layout="paged"``
                (constructor default or per-call) selects the paged
                path; the slotted path stays as the differential
                baseline. ``spec=SpecConfig(...)`` (constructor default
                or per-call) turns on speculative decoding — greedy
                token streams are unchanged by construction.
                ``attention_backend=`` (constructor default or
                per-call) picks the decode/verify attention backend
                (DESIGN.md §4): the block-paged Pallas kernel walks
                block tables directly — no dense gather per step — and
                each backend gets its own statically-bound jitted step
                family, so switching never retraces another backend's
                executables.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.serve.request import Request, ServeStats  # noqa: F401 (re-export)
from repro.serve.scheduler import Scheduler
from repro.serve.telemetry import TID_BACKEND, get_telemetry

log = logging.getLogger("repro.serve")


class ServingEngine:
    def __init__(
        self,
        model,
        params,
        *,
        max_seq: int,
        temperature: float = 0.0,
        decode_plan=None,
        max_batch: Optional[int] = None,
        kv_layout: str = "slot",
        block_size: int = 8,
        num_blocks: Optional[int] = None,
        prefix_cache: bool = True,
        spec=None,
        attention_backend: Optional[str] = None,
        chunk_size: Optional[int] = None,
        mesh=None,
        telemetry=None,
    ):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.temperature = temperature
        self.max_batch = max_batch  # default slot-pool size for serve()
        self.kv_layout = kv_layout  # default layout for serve()/scheduler()
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.prefix_cache = prefix_cache
        self.spec = spec  # default SpecConfig for serve()/scheduler()
        self.chunk_size = chunk_size  # default chunked-prefill token budget
        # flight recorder + metrics (DESIGN.md §8): every scheduler this
        # engine makes shares the tracer (and the stats registry the
        # windowed metrics live in). Default is the module-global
        # telemetry, which is disabled — the hard off-switch.
        self.telemetry = telemetry if telemetry is not None else get_telemetry()
        self.stats = ServeStats()
        # serving tensor parallelism (DESIGN.md §5): a mesh with a 'model'
        # axis head-partitions the paged pool and runs the decode/verify
        # steps under shard_map. Head counts that do not divide the axis
        # fall back LOUDLY to replicated serving via the ShardingRules
        # drop-rule — tokens are identical either way, only the layout
        # changes, so a warning (never silence, never a crash) is right.
        # Warned ONCE per (cfg, mesh): repeated serve(mesh=) calls on the
        # same engine re-check but neither re-warn nor re-append the
        # fallback record (the regression test counts warnings).
        self._mesh_warned: set = set()
        self.mesh_fallbacks: list[str] = []
        self.mesh = self._check_mesh(mesh)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            # params are replicated once, up front: every rank slices its
            # own head block inside the step, so no per-step weight moves
            self.params = jax.device_put(
                params, NamedSharding(self.mesh, P())
            )
        # the decode/verify attention backend is resolved ONCE, here,
        # before anything is jitted (DESIGN.md §4): each backend gets its
        # own statically-bound jitted step family in ``self._steps``, so
        # switching backends (per serve()/scheduler() call) reuses that
        # backend's compiled executables and can never retarget — or
        # retrace — another backend's traces. Mesh-aware: on a non-TPU
        # mesh "kernel" resolves to "interpret" (the kernel composes with
        # shard_map per-shard instead of falling back to reference).
        self.attention_backend = kernel_ops.resolve_attention_backend(
            attention_backend, mesh=self.mesh
        )
        # engine-owned jitted steps, shared by every scheduler this engine
        # makes: repeated generate()/serve() calls reuse the executables
        self._prefill = jax.jit(lambda p, t, **kw: model.prefill(p, t, max_seq, **kw))
        self._prefill_prefix = None  # lazy: attention families only
        self._steps: dict[str, dict] = {}  # backend → jitted decode/verify family
        self._plan_steps: dict = {}  # (plan key, pool size) → jitted plan step
        self._decode_plan = None
        if decode_plan is not None:
            self.set_decode_plan(decode_plan)

    def _check_mesh(self, mesh):
        """The engine's serving mesh, or None after the loud GQA
        fallback: head counts that don't divide the 'model' axis mean
        ``ShardingRules`` drops the head mapping, and the engine serves
        replicated — warned, never silent, never wrong tokens. A pure
        "seq"-axis mesh always passes: the kv-sequence split partitions
        blocks, not heads, and the slot layout imposes no divisibility
        constraint. The fallback record is kept (deduped) on the engine's
        ``mesh_fallbacks`` and the warning fires once per (cfg, mesh) —
        re-serving through the same fallen-back engine stays quiet."""
        if mesh is None or mesh.shape.get("model", 1) == 1:
            return mesh
        from repro.parallel.sharding import ShardingRules

        cfg = self.model.cfg
        rules = ShardingRules(mesh, cfg)
        tp = mesh.shape["model"]
        if rules.table["kv_heads"] is None or cfg.n_heads % tp:
            record = (
                f"kv_heads:{cfg.n_kv_heads}/heads:{cfg.n_heads} ∤ mesh "
                f"model({tp}); serving replicated"
            )
            if record not in self.mesh_fallbacks:
                self.mesh_fallbacks.append(record)
                if self.telemetry.enabled:
                    self.telemetry.count(
                        "serve.mesh_fallbacks", registry=self.stats.registry
                    )
                    self.telemetry.tracer.instant(
                        "mesh-fallback", "backend", tid=TID_BACKEND,
                        args={"record": record},
                    )
            rules.fallbacks.append(record)
            key = (id(cfg), tuple(sorted(mesh.shape.items())))
            if key not in self._mesh_warned:
                self._mesh_warned.add(key)
                log.warning(
                    "serving mesh dropped: n_kv_heads=%d/n_heads=%d do not "
                    "divide mesh axis 'model' (size %d) — serving replicated "
                    "(ShardingRules fallbacks: %s)",
                    cfg.n_kv_heads, cfg.n_heads, tp, rules.fallbacks,
                )
            return None
        return mesh

    def _step_fns(self, backend: str) -> dict:
        """The jitted decode/verify family for ``backend``, built lazily
        (only attention families page, only SPEC_FAMILIES verify) and
        cached per backend."""
        fns = self._steps.setdefault(backend, {})
        if "decode" not in fns:
            fns["decode"] = self.model.jit_step("decode_step", backend)
        return fns

    def _paged_fns(self, backend: str):
        fns = self._step_fns(backend)
        if "decode_paged" not in fns:
            fns["decode_paged"] = (
                self.model.sharded_paged_step("decode_step_paged", self.mesh, backend)
                if self.mesh is not None
                else self.model.jit_step("decode_step_paged", backend)
            )
        if self._prefill_prefix is None:
            model, max_seq = self.model, self.max_seq
            self._prefill_prefix = jax.jit(
                lambda p, t, pk, pv, **kw: model.prefill_with_prefix(
                    p, t, pk, pv, max_seq, **kw
                )
            )
        return fns["decode_paged"], self._prefill_prefix

    # ------------------------------------------------------------------
    # the decode step as an advisable region (requests = work items)

    def _decode_cache_spec(self, cache):
        """(treedef, per-leaf batch-axis index) of the decode cache."""
        leaves, treedef = jax.tree.flatten(cache)
        axes = tuple(jax.tree.leaves(self.model.cache_batch_axes(cache)))
        assert len(axes) == len(leaves)
        return treedef, axes

    def _per_request_decode(self, treedef, axes):
        """Per-item fn over (token, batchless cache leaves): one request's
        decode step — what relic_pfor co-schedules across requests.
        Decodes through the engine's resolved attention backend, like
        every other step family."""
        model, params, backend = self.model, self.params, self.attention_backend

        def fn(item):
            tok, leaves = item
            cache = jax.tree.unflatten(
                treedef, [jnp.expand_dims(l, ax) for l, ax in zip(leaves, axes)]
            )
            logits, new_cache = model.decode_step(
                params, cache, tok.reshape(1, 1), backend=backend
            )
            new_leaves = [
                jnp.moveaxis(l, ax, 0)[0]
                for l, ax in zip(jax.tree.leaves(new_cache), axes)
            ]
            return logits[0], new_leaves

        return fn

    def _decode_items(self, cache, tok, axes):
        leaves = jax.tree.leaves(cache)
        return (tok, [jnp.moveaxis(l, ax, 0) for l, ax in zip(leaves, axes)])

    def decode_region(
        self,
        prompts: jax.Array,
        *,
        name: str = "serve-decode",
        seed: int = 0,
        task_flops: Optional[float] = None,
        task_bytes: Optional[float] = None,
        task_chain: int = 0,
        force: bool = False,
    ):
        """Expose one decode step as an Aira ``Region``.

        Items are the batch of concurrent requests. The attached dynamic
        trace records each request touching only its own cache slice
        (disjoint by construction), so the dependence stages clear and
        the overlap gate decides. ``seed`` seeds the advisory trace's
        first sampled token, so traces aren't silently correlated with
        serving seeds. Default napkin cost: weight-streaming decode —
        2·n_params FLOPs and n_params·4 bytes per request-token (batched
        decode is bandwidth-bound, which is exactly why the gate usually
        says no and latency-critical deployments ``force``).
        """
        from repro.core.adviser import Region
        from repro.core.deps import MemoryTrace

        logits, cache = self._prefill(self.params, prompts)
        tok = self._sample(logits, jax.random.key(seed))
        treedef, axes = self._decode_cache_spec(cache)
        items = self._decode_items(cache, tok, axes)
        n_params = sum(l.size for l in jax.tree.leaves(self.params))
        batch = int(tok.shape[0])
        trace = MemoryTrace(
            reads=[[i] for i in range(batch)], writes=[[i] for i in range(batch)]
        )
        return Region(
            name=name,
            fn=self._per_request_decode(treedef, axes),
            items=items,
            task_flops=2.0 * n_params if task_flops is None else task_flops,
            task_bytes=4.0 * n_params if task_bytes is None else task_bytes,
            task_chain=task_chain,
            vector=False,
            trace=trace,
            force=force,
        )

    def set_decode_plan(self, plan) -> None:
        """Route the decode step through an accepted ``RegionPlan`` (as
        produced by advising ``decode_region`` — stack combine). Applies
        to schedulers created from here on (masked execution over the
        active-slot view)."""
        if plan is not None and plan.key.combine != "stack":
            raise ValueError("decode plan must preserve per-request order (combine='stack')")
        self._decode_plan = plan

    # ------------------------------------------------------------------
    # serving entries
    def _spec_fns(self, layout: str, backend: str):
        fns = self._step_fns(backend)
        if "verify" not in fns:
            fns["verify"] = self.model.jit_step("verify_step", backend)
        if layout == "paged" and "verify_paged" not in fns:
            fns["verify_paged"] = (
                self.model.sharded_paged_step("verify_step_paged", self.mesh, backend)
                if self.mesh is not None
                else self.model.jit_step("verify_step_paged", backend)
            )
        return fns["verify"], fns.get("verify_paged")

    def prime(
        self,
        max_batch: Optional[int] = None,
        *,
        ks=(0, 2, 4, 8),
        backends=None,
        kv_layout: Optional[str] = None,
        reps: int = 2,
    ) -> dict:
        """Pre-jit (and measure) the K × backend decode/verify grid an
        online controller can switch across (DESIGN.md §9).

        Each candidate depth is one ``[max_batch, k+1]`` verify trace in
        the shared jitted verify fn (jit caches per input shape) and
        each backend one entry in the engine's step-fn cache, so after
        this every mid-serve switch the controller makes is a trace-
        cache hit — the drift benchmark pins that with trace counters.
        ``max_batch`` must match the pool size later serves use (pool
        shapes are static). Depths are clamped to ``(0,)`` for model
        families without a rewindable cache. Runs against a throwaway
        pool (all rows dead — writes route to the null block / junk
        slots), timing ``reps`` calls per cell, and returns
        ``{"cells": {backend: {k: ms}}, "ks", "backends", ...}`` —
        feed it to ``OnlineAdviser.seed_costs`` so the first decision
        prices measured numbers."""
        from repro.models.model import SPEC_FAMILIES
        from repro.serve.kv_cache import PagedKVCache

        mb = int(max_batch or self.max_batch or 4)
        layout = kv_layout or self.kv_layout
        if self.model.cfg.family not in SPEC_FAMILIES:
            ks = (0,)
        ks = tuple(sorted({int(k) for k in ks}))
        names = backends if backends else (self.attention_backend,)
        names = tuple(
            dict.fromkeys(
                kernel_ops.resolve_attention_backend(b, mesh=self.mesh)
                for b in names
            )
        )
        tok = jnp.zeros((mb, 1), jnp.int32)
        cells: dict[str, dict[int, float]] = {}

        def _time(fn) -> float:
            jax.block_until_ready(fn())  # compile (not timed)
            t0 = time.perf_counter()
            for _ in range(max(1, reps)):
                jax.block_until_ready(fn())
            return (time.perf_counter() - t0) / max(1, reps) * 1e3

        for backend in names:
            per_k: dict[int, float] = {}
            if layout == "paged":
                kv = PagedKVCache(
                    self.model, mb, self.max_seq,
                    block_size=self.block_size, num_blocks=self.num_blocks,
                    prefix_cache=False, mesh=self.mesh,
                )
                pool, tables, lens = kv.kernel_inputs()
                decode_paged, _ = self._paged_fns(backend)
                verify_paged = None
                if any(k > 0 for k in ks):
                    _, verify_paged = self._spec_fns("paged", backend)
                for k in ks:
                    if k == 0:
                        per_k[0] = _time(
                            lambda: decode_paged(self.params, pool, tables, lens, tok)[0]
                        )
                    else:
                        blk = jnp.zeros((mb, k + 1), jnp.int32)
                        per_k[k] = _time(
                            lambda blk=blk: verify_paged(
                                self.params, pool, tables, lens, blk
                            )[0]
                        )
            else:
                cache = self.model.init_cache(mb, self.max_seq)
                decode = self._step_fns(backend)["decode"]
                verify = None
                if any(k > 0 for k in ks):
                    verify, _ = self._spec_fns("slot", backend)
                for k in ks:
                    if k == 0:
                        per_k[0] = _time(lambda: decode(self.params, cache, tok)[0])
                    else:
                        blk = jnp.zeros((mb, k + 1), jnp.int32)
                        per_k[k] = _time(
                            lambda blk=blk: verify(self.params, cache, blk)[0]
                        )
            cells[backend] = per_k
        return {
            "cells": cells,
            "ks": ks,
            "backends": names,
            "max_batch": mb,
            "layout": layout,
        }

    def scheduler(
        self,
        max_batch: int,
        *,
        seed: int = 0,
        kv_layout: Optional[str] = None,
        spec=None,
        attention_backend: Optional[str] = None,
        chunk_size: Optional[int] = None,
        telemetry=None,
        controller=None,
    ) -> Scheduler:
        """A fresh continuous-batching scheduler over ``max_batch`` rows
        (slots, or paged block tables), sharing this engine's stats,
        jitted steps, and decode plan. ``spec`` overrides the engine's
        default ``SpecConfig`` (``SpecConfig(k=0)`` disables);
        ``attention_backend`` overrides the engine default — each
        backend's jitted step family is cached separately, so switching
        is retrace-free after first use. ``chunk_size`` overrides the
        engine's chunked-prefill budget (``0`` disables for this call).
        ``telemetry`` overrides the engine's flight recorder for this
        scheduler (the instrumented-vs-off overhead benchmark serves the
        same warmed engine both ways). ``controller`` attaches an online
        adviser (DESIGN.md §9) that re-decides K/backend/admission from
        the windowed telemetry mid-run — the scheduler switches through
        this engine's pre-warmed step families (``prime()`` makes every
        switch a trace-cache hit); when the controller carries positive
        candidate depths and no ``spec`` is set, a default n-gram
        ``SpecConfig(k=max(ks))`` is installed so the margin and drafter
        cover the deepest arm."""
        layout = kv_layout or self.kv_layout
        if self.mesh is not None and layout != "paged":
            raise ValueError(
                "a serving mesh shards the paged block pool; the slotted "
                "layout has no head-partitioned storage — use "
                "kv_layout='paged' (or build the engine without mesh=)"
            )
        spec = spec if spec is not None else self.spec
        chunk = chunk_size if chunk_size is not None else self.chunk_size
        chunk = None if not chunk else int(chunk)
        backend = kernel_ops.resolve_attention_backend(
            attention_backend or self.attention_backend, mesh=self.mesh
        )
        if controller is not None:
            ctl_ks = tuple(getattr(controller, "ks", (0,)))
            kmax = max(ctl_ks) if ctl_ks else 0
            if kmax > 0 and (spec is None or spec.k < kmax):
                from repro.serve.speculative import SpecConfig

                spec = (
                    SpecConfig(k=kmax, drafter="ngram")
                    if spec is None
                    else dataclasses.replace(spec, k=kmax)
                )
        if self._decode_plan is not None and backend != self.attention_backend:
            # the plan's per-request fn captured the engine backend when
            # the region was advised; honoring a different per-call
            # backend here would silently run (and mislabel) the old one
            raise ValueError(
                f"attention_backend={backend!r} cannot override a decode "
                f"plan advised under {self.attention_backend!r} — re-advise "
                "decode_region() on an engine built with that backend"
            )
        paged_kw = {}
        if layout == "paged":
            decode_paged, prefill_prefix = self._paged_fns(backend)
            paged_kw = dict(
                block_size=self.block_size,
                num_blocks=self.num_blocks,
                prefix_cache=self.prefix_cache,
                paged_decode_fn=decode_paged,
                prefix_prefill_fn=prefill_prefix,
                mesh=self.mesh,
            )
        if spec is not None and spec.k > 0:
            verify, verify_paged = self._spec_fns(layout, backend)
            paged_kw.update(verify_fn=verify, paged_verify_fn=verify_paged)
        if chunk is not None:
            fns = self._step_fns(backend)
            if "prefill_chunk" not in fns:
                fns["prefill_chunk"] = self.model.jit_step("prefill_chunk", backend)
            paged_kw.update(chunk_prefill_fn=fns["prefill_chunk"])
        if controller is not None:
            # live backend re-decision resolves into THIS engine's shared
            # step-fn caches — after prime() every switch is a cache hit
            _spec, _chunk, _layout = spec, chunk, layout

            def _resolver(b):
                rb = kernel_ops.resolve_attention_backend(b, mesh=self.mesh)
                out = {"backend": rb, "decode": self._step_fns(rb)["decode"]}
                if _layout == "paged":
                    out["decode_paged"], _ = self._paged_fns(rb)
                if _spec is not None and _spec.k > 0:
                    out["verify"], out["verify_paged"] = self._spec_fns(_layout, rb)
                if _chunk is not None:
                    f = self._step_fns(rb)
                    if "prefill_chunk" not in f:
                        f["prefill_chunk"] = self.model.jit_step("prefill_chunk", rb)
                    out["prefill_chunk"] = f["prefill_chunk"]
                return out

            paged_kw.update(controller=controller, step_fn_resolver=_resolver)
        return Scheduler(
            self.model,
            self.params,
            max_batch=max_batch,
            max_seq=self.max_seq,
            temperature=self.temperature,
            decode_plan=self._decode_plan,
            stats=self.stats,
            seed=seed,
            kv_layout=layout,
            spec=spec,
            attention_backend=backend,
            chunk_size=chunk,
            prefill_fn=self._prefill,
            decode_fn=self._step_fns(backend)["decode"],
            plan_step_cache=self._plan_steps,
            telemetry=telemetry if telemetry is not None else self.telemetry,
            **paged_kw,
        )

    def serve(
        self,
        requests,
        *,
        max_batch: Optional[int] = None,
        seed: int = 0,
        kv_layout: Optional[str] = None,
        spec=None,
        attention_backend: Optional[str] = None,
        chunk_size: Optional[int] = None,
        mesh=None,
        telemetry=None,
        controller=None,
    ) -> dict:
        """Continuous-batching entry: drive ``requests`` (each with its
        own arrival time, prompt length, and token budget) to completion
        through a slotted or block-paged pool, optionally speculating
        ``spec.k`` draft tokens per verify (greedy streams unchanged —
        ``spec`` usually comes from ``speculative.advise_depth``),
        optionally overriding the attention backend for this run,
        optionally chunking prefill (``chunk_size`` tokens per step;
        ``0`` forces monolithic), and optionally closed-loop controlled
        (``controller=OnlineAdviser(...)`` re-decides K/backend/
        admission from live telemetry — see ``scheduler()``; run
        ``prime()`` first so every switch is retrace-free). ``mesh``
        must match the engine's serving mesh (the sharded step family
        and the replicated params are built against it at construction);
        passing it on a mesh-less engine adopts it, provided no step has
        been jitted yet. Returns rid → generated tokens."""
        if mesh is not None and mesh is not self.mesh:
            if self.mesh is not None:
                raise ValueError(
                    "serve(mesh=) differs from the engine's mesh — the "
                    "sharded step family is built against the constructor "
                    "mesh; create one engine per mesh"
                )
            # check BEFORE the jitted-steps guard: a mesh the GQA fallback
            # drops adopts nothing, so re-serving the same mesh through an
            # engine that has already jitted replicated steps is fine (and
            # warns only once — _check_mesh dedupes)
            checked = self._check_mesh(mesh)
            if checked is not None:
                if self._steps or self._prefill_prefix is not None:
                    raise ValueError(
                        "serve(mesh=) after steps were jitted without a mesh "
                        "— pass mesh= to the ServingEngine constructor instead"
                    )
                from jax.sharding import NamedSharding, PartitionSpec as P

                self.mesh = checked
                self.params = jax.device_put(
                    self.params, NamedSharding(self.mesh, P())
                )
                self.attention_backend = kernel_ops.resolve_attention_backend(
                    self.attention_backend, mesh=self.mesh
                )
        requests = list(requests)
        mb = max_batch or self.max_batch or max(1, min(8, len(requests)))
        return self.scheduler(
            mb, seed=seed, kv_layout=kv_layout, spec=spec,
            attention_backend=attention_backend, chunk_size=chunk_size,
            telemetry=telemetry, controller=controller,
        ).run(requests)

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.temperature, axis=-1)

    def generate(self, prompts: jax.Array, n_steps: int, *, seed: int = 0, patch_embeds=None):
        """prompts [B, S0] → generated tokens [B, n_steps].

        Fixed-batch compatibility wrapper: B requests all arriving at
        t=0 into a B-slot pool — one full continuous batch. Stats start
        clean every call."""
        B = int(prompts.shape[0])
        if n_steps <= 0:
            self.stats.reset()
            return jnp.zeros((B, 0), jnp.int32)
        reqs = [
            Request(
                prompt=prompts[i],
                max_new_tokens=n_steps,
                patch_embeds=None if patch_embeds is None else patch_embeds[i],
            )
            for i in range(B)
        ]
        out = self.scheduler(B, seed=seed).run(reqs)
        return jnp.stack([jnp.asarray(out[r.rid]) for r in reqs], axis=0)
