"""Speculative decoding: draft streams, verify/rollback, depth advice.

The paper's central move is a lightweight helper stream running beside a
latency-critical main thread, committed only after an SMT-aware
simulation predicts a gain. Speculative decoding is that architecture at
the serving layer (DESIGN.md §3.2): a cheap *draft* stream runs ahead of
the target model (`DraftSource`), one fixed-shape `Model.verify_step`
forward accepts or rejects its proposals under greedy equivalence, the
KV pools rewind the rejected tail (`truncate_row`), and an advisory cost
model — `core.tools.SpeculationAdvisorTool`, the serving analogue of
`OverlapSimTool`'s simulate-before-commit gate — decides per workload
whether and how deep to speculate (K ∈ {0, 2, 4, 8}).

Two drafters ship:

* ``NGramDraftSource`` — prompt-lookup decoding: propose the
  continuation of the most recent earlier occurrence of the current
  tail n-gram in the request's own history (prompt + generated). Free
  (no second model, no device state), and strong on templated or
  self-repetitive generations.
* ``ModelDraftSource`` — a small ``ModelConfig``-driven draft model
  sharing the target's tokenizer space, with its own slotted cache pool
  aligned row-for-row with the scheduler's.

Both are pool-shaped: ``propose`` returns ``[max_batch, K]`` over the
full fixed row pool (dead rows carry junk that the verify routes to
scratch), so the scheduler's draft→verify round is one fused step whose
only per-request quantity — the acceptance count — is data, not shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class DraftSource(Protocol):
    """One draft stream. All hooks are pool-shaped (see module doc)."""

    def bind(self, max_batch: int, max_seq: int) -> None:
        """Size internal state to the scheduler's row pool (called once
        per scheduler, before any propose)."""
        ...

    def on_admit(self, row: int, req) -> None:
        """A request entered decode on ``row`` (catch up on its prompt)."""
        ...

    def propose(self, active: dict, tok: np.ndarray) -> np.ndarray:
        """K draft tokens per row following ``tok`` [max_batch] (the
        pending last-committed token). Returns [max_batch, K] int32;
        rows not in ``active`` may carry anything."""
        ...

    def rollback(self, n_rejected: np.ndarray) -> None:
        """Per-row rejected-entry counts from the verify (the same
        vector the KV pools truncate by); stateful drafters rewind."""
        ...


@dataclass
class SpecConfig:
    """One speculation policy: depth K plus which draft stream runs.

    ``k=0`` disables speculation (the scheduler takes the plain decode
    path); ``drafter`` is ``"ngram"``, ``"model"`` (requires
    ``draft_model``/``draft_params``), or a ``DraftSource`` instance.
    """

    k: int = 4
    drafter: Any = "ngram"
    ngram: tuple = (3, 2, 1)  # tail n-gram sizes tried, longest first
    draft_model: Any = None  # repro.models.Model (drafter="model")
    draft_params: Any = None

    def make_drafter(self, attention_backend=None):
        """Build the draft stream; a model drafter decodes through
        ``attention_backend`` (the scheduler passes its resolved
        backend, so draft and target ride the same kernel path)."""
        if self.k <= 0:
            return None
        if self.drafter == "ngram":
            return NGramDraftSource(self.k, self.ngram)
        if self.drafter == "model":
            if self.draft_model is None:
                raise ValueError("drafter='model' needs draft_model/draft_params")
            return ModelDraftSource(
                self.draft_model, self.draft_params, self.k,
                attention_backend=attention_backend,
            )
        return self.drafter


class NGramDraftSource:
    """Prompt-lookup drafter: no second model.

    For each live row, find the most recent earlier occurrence of the
    history's tail n-gram (longest ``ngram`` size first) and propose
    the K tokens that followed it, cycle-extended when the match sits
    near the end (greedy loops — the common case for self-repetitive
    generations — then verify at ~100% acceptance). With no match the
    proposal degenerates to repeating the last token; wrong guesses
    only cost their share of the fixed-shape verify."""

    def __init__(self, k: int, ngram=(3, 2, 1)):
        self.k = int(k)
        self.ngrams = tuple(int(n) for n in ngram)
        self._max_batch = 0

    def bind(self, max_batch: int, max_seq: int) -> None:
        self._max_batch = int(max_batch)

    def on_admit(self, row: int, req) -> None:
        pass  # the request history IS the state

    def set_k(self, k: int) -> None:
        """Re-depth the proposal window live (online adviser K
        re-decision): the lookup is stateless, so this only resizes the
        proposal matrix for subsequent rounds."""
        self.k = int(k)

    def propose(self, active: dict, tok: np.ndarray) -> np.ndarray:
        out = np.zeros((self._max_batch, self.k), np.int32)
        for row, req in active.items():
            hist = np.concatenate(
                [np.asarray(req.prompt, np.int32), np.asarray(req.tokens, np.int32)]
            )
            out[row] = self._lookup(hist)
        return out

    def rollback(self, n_rejected: np.ndarray) -> None:
        pass

    def _lookup(self, hist: np.ndarray) -> np.ndarray:
        cont = None
        for n in self.ngrams:
            if len(hist) <= n:
                continue
            tail = hist[-n:]
            for j in range(len(hist) - n - 1, -1, -1):
                if np.array_equal(hist[j : j + n], tail):
                    cont = hist[j + n : j + n + self.k]
                    break
            if cont is not None and len(cont):
                break
            cont = None
        if cont is None or not len(cont):
            cont = hist[-1:]
        out = np.empty((self.k,), np.int32)
        for i in range(self.k):
            out[i] = cont[i % len(cont)]  # cycle-extend short matches
        return out


class ModelDraftSource:
    """K-token greedy drafter backed by a small draft model sharing the
    target's tokenizer space.

    Owns a slotted decode cache aligned row-for-row with the
    scheduler's pool: the prompt is prefilled on admission, each
    propose round runs K sequential greedy decode steps plus ONE
    catch-up step (processing the K-th draft, so full acceptance
    leaves no hole in the draft cache), and ``rollback`` truncates by
    the same per-row vector as the target pool — after which the draft
    cache holds exactly the committed stream, mirroring the target.
    The draft rows carry ``k+1`` tokens of speculative overhang, hence
    the padded ``max_seq``."""

    def __init__(self, model, params, k: int, attention_backend=None):
        from repro.models.model import SPEC_FAMILIES

        if model.cfg.family not in SPEC_FAMILIES:
            raise ValueError(
                f"draft model must be a {SPEC_FAMILIES} family (rewindable "
                f"cache), got {model.cfg.family!r}"
            )
        self.model = model
        self.params = params
        self.k = int(k)
        self._k_max = int(k)  # construction depth sizes the cache overhang
        # the draft stream decodes through the same attention backend
        # as the target (the scheduler passes its resolved backend via
        # make_drafter), bound statically like every jitted step
        self._decode = model.jit_step("decode_step", attention_backend)
        self._prefill = None  # needs max_seq: built in bind()
        self.cache = None

    def set_k(self, k: int) -> None:
        """Re-depth the draft loop live (online adviser K re-decision).
        The cache overhang was sized for the construction depth, so the
        live depth may only move within it."""
        if not 0 < int(k) <= self._k_max:
            raise ValueError(
                f"live k={k} outside (0, {self._k_max}] — the draft cache "
                f"overhang was bound for k={self._k_max}"
            )
        self.k = int(k)

    def bind(self, max_batch: int, max_seq: int) -> None:
        self._max_seq = int(max_seq) + self._k_max + 1  # speculative overhang
        model = self.model
        seq = self._max_seq
        self._prefill = jax.jit(
            lambda p, t, n: model.prefill(p, t, seq, prompt_len=n)
        )
        self.cache = model.init_cache(int(max_batch), seq)

    def on_admit(self, row: int, req) -> None:
        # catch up on the request's committed history: the prompt, plus
        # — when resuming after a preemption — every generated token but
        # the pending last one (it is fed to propose, never pre-cached).
        # Pow2-bucketed (pad + per-row length): SPEC_FAMILIES are all
        # pad-safe, and one trace per bucket beats one per prompt length.
        from repro.models.model import prefill_bucket

        hist = np.asarray(req.prompt, np.int32)
        if len(req.tokens) > 1:
            hist = np.concatenate([hist, np.asarray(req.tokens[:-1], np.int32)])
        S = len(hist)
        W = prefill_bucket(S, self._max_seq)
        padded = np.zeros((1, W), np.int32)
        padded[0, :S] = hist
        _, cache1 = self._prefill(
            self.params, jnp.asarray(padded), jnp.asarray([S], jnp.int32)
        )
        self.cache = self.model.write_cache_slot(self.cache, cache1, row)

    def propose(self, active: dict, tok: np.ndarray) -> np.ndarray:
        from repro.serve.telemetry import get_telemetry

        cur = jnp.asarray(np.asarray(tok, np.int32))
        cache = self.cache
        out = []
        with get_telemetry().annotate("serve.draft_model"):
            for _ in range(self.k):
                logits, cache = self._decode(self.params, cache, cur[:, None])
                cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                out.append(cur)
            # catch-up: process the K-th draft so a fully-accepted round
            # leaves the draft cache one-for-one with the target's
            _, cache = self._decode(self.params, cache, cur[:, None])
        self.cache = cache
        return np.stack([np.asarray(t) for t in out], axis=1).astype(np.int32)

    def rollback(self, n_rejected: np.ndarray) -> None:
        vec = jnp.asarray(np.asarray(n_rejected, np.int32))
        self.cache["len"] = jnp.maximum(self.cache["len"] - vec, 0)


# ---------------------------------------------------------------------------
# depth advice (the serving analogue of advise-then-execute)


def advise_depth(
    engine,
    workload_fn,
    *,
    drafter: Any = "ngram",
    ks=(0, 2, 4, 8),
    max_batch: int = 4,
    threshold: float = 0.02,
    draft_model=None,
    draft_params=None,
    seed: int = 0,
):
    """Probe-measure this workload, then let ``SpeculationAdvisorTool``
    pick the speculation depth.

    Runs ``workload_fn()`` (a fresh request list per call — requests
    are stateful) twice through ``engine``: once plain (the K=0 decode
    cost) and once at ``max(ks)`` (draft cost, verify cost, acceptance
    rate). The tool prices expected per-output-token latency at every
    candidate K from those measurements — interpolating verify cost
    between the probed depths — and gates on ``threshold`` predicted
    gain, exactly the shape of ``OverlapSimTool``'s simulate stage.
    Returns ``(SpecConfig, SpecMeasurement, log_line)``;
    ``engine.serve(spec=...)`` honors the decision.
    """
    from repro.core.tools import SpecMeasurement, SpeculationAdvisorTool

    kmax = max(ks)
    if kmax <= 0:
        raise ValueError("ks needs at least one positive candidate depth")
    spec_kw = dict(drafter=drafter, draft_model=draft_model, draft_params=draft_params)
    engine.serve(workload_fn(), max_batch=max_batch, seed=seed, spec=SpecConfig(k=0))
    decode_ms = engine.stats.percentile(50)
    engine.serve(
        workload_fn(), max_batch=max_batch, seed=seed,
        spec=SpecConfig(k=kmax, **spec_kw),
    )
    s = engine.stats
    n_drafted = max(1, kmax * s.spec_steps)
    meas = SpecMeasurement(
        draft_ms_per_token=float(np.sum(s.draft_ms)) / n_drafted,
        verify_ms={0: decode_ms, kmax: s.percentile(50, "verify_ms")},
        acceptance_rate=s.acceptance_rate,
    )
    tool = SpeculationAdvisorTool(ks=tuple(ks))
    k, _gain, log = tool.choose(meas, threshold=threshold)
    return SpecConfig(k=k, **spec_kw), meas, log
