"""internlm2-20b [dense]: GQA.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544
[arXiv:2403.17297; hf]. FSDP parameter sharding (20B params).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internlm2-20b",
        family="dense",
        num_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        train_accum=32,
        remat="full",
        param_sharding="fsdp",
    )
)
