"""granite-moe-1b-a400m [moe]: 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. Vocab padded 49155→49408.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        num_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        n_experts=32,
        top_k=8,
        tie_embeddings=True,
        train_accum=4,
        param_sharding="tp",
    )
)
