"""dbrx-132b [moe]: fine-grained MoE, 16 experts top-4.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352
[hf:databricks/dbrx-base; unverified]. EP: one expert per model shard.
Largest assigned model → FSDP parameter sharding over the data axis.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="dbrx-132b",
        family="moe",
        num_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        n_experts=16,
        top_k=4,
        train_accum=16,
        remat="full",
        param_sharding="fsdp",
    )
)
