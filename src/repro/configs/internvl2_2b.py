"""internvl2-2b [vlm]: InternViT + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf]. The InternViT frontend is a STUB: ``input_specs``
supplies precomputed patch embeddings (256 patches) that are projected and
prepended to the token embeddings. Vocab padded 92553→92672.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        n_frontend_tokens=256,
        train_accum=8,
        param_sharding="tp",
    )
)
