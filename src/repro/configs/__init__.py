"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_is_runnable,
    cells,
    get_config,
    get_shape,
    list_configs,
    pad_to_multiple,
    register,
)

# Importing each module registers its config.
from repro.configs import (  # noqa: F401,E402
    musicgen_large,
    zamba2_2p7b,
    dbrx_132b,
    granite_moe_1b,
    smollm_135m,
    phi3_medium_14b,
    stablelm_3b,
    internlm2_20b,
    mamba2_370m,
    internvl2_2b,
)

ALL_ARCHS = list_configs()
