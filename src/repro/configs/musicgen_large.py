"""musicgen-large [audio]: decoder-only LM over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 → MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]. The EnCodec frontend is a stub: ``input_specs``
supplies codec token ids (the decoder's native input).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        train_accum=8,
        kv_quant=True,
        param_sharding="tp",
    )
)
