"""phi3-medium-14b [dense]: RoPE SwiGLU GQA.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352
[arXiv:2404.14219; unverified]. 40 heads ∤ 16 → sequence-parallel attention;
FSDP parameter sharding (14B params).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17920,
        vocab_size=100352,
        train_accum=8,
        remat="full",
        param_sharding="fsdp",
    )
)
