"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf]. One attention block with *shared weights* is applied
every 6th layer (per-site KV caches). Sub-quadratic → runs long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,  # used by the shared block's MLP
        vocab_size=32000,
        ssm_state=64,
        attn_every=6,
        sub_quadratic=True,
        kv_quant=True,
        tie_embeddings=True,
        train_accum=16,
        param_sharding="tp",
    )
)
