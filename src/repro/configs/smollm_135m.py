"""smollm-135m [dense]: llama-arch small.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]. 9 heads ∤ 16 → attention head-TP
inapplicable; sharding falls back to sequence parallelism (DESIGN.md §6.1).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="smollm-135m",
        family="dense",
        num_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab_size=49152,
        tie_embeddings=True,
        train_accum=2,
        param_sharding="tp",
    )
)
