"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060;
unverified]. Attention-sharding advice inapplicable (DESIGN.md
§Arch-applicability) — the adviser targets the SSD chunk scan instead.
Sub-quadratic → runs long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        sub_quadratic=True,
        tie_embeddings=True,
        train_accum=4,
        param_sharding="tp",
    )
)
