"""Config system: model architectures and input-shape cells.

Every assigned architecture is a ``ModelConfig``; every workload shape is a
``ShapeConfig``. A (ModelConfig, ShapeConfig) pair is one dry-run /
roofline cell. Configs are plain frozen dataclasses so they can be hashed,
diffed and logged by the adviser.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


def pad_to_multiple(x: int, m: int) -> int:
    return int(math.ceil(x / m) * m)


@dataclass(frozen=True)
class ShapeConfig:
    """One workload shape (the paper's 'granularity' axis at LM scale)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. ``family`` selects the block structure:

    dense  — pre-norm GQA transformer (llama-style RoPE/SwiGLU)
    moe    — dense attention + top-k routed expert MLP (EP over 'model')
    ssm    — Mamba2 / SSD, attention-free
    hybrid — Mamba2 backbone with a shared attention block every
             ``attn_every`` layers (Zamba2-style, shared weights)
    audio  — dense decoder over codec tokens (frontend stubbed)
    vlm    — dense decoder with prepended patch embeddings (frontend stubbed)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid ---
    attn_every: int = 0  # zamba2: shared attention block period
    # --- frontends (stubs) ---
    n_frontend_tokens: int = 0  # vlm: image patches prepended per sequence
    # --- numerics / structure ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # --- distribution policy (adviser-tunable) ---
    param_sharding: str = "tp"  # "tp" | "fsdp"
    train_accum: int = 1  # gradient-accumulation microbatches (train_4k)
    zero2: int = 0  # 1 = gather-once/reduce-once accumulation (§Perf #phi3)
    remat: str = "dots"  # "none" | "dots" | "full"
    attn_chunk: int = 512  # kv-block size for chunked attention
    causal_blocking: str = "masked"  # "masked" | "triangular" (hillclimbed)
    kv_quant: bool = False  # int8 KV cache (per-token/head scales) — §Perf
    attn_flat_tp: bool = False  # shard flattened q/kv projection dims when
    # n_heads ∤ mesh (keeps attn weights + grads sharded) — §Perf C.4
    sub_quadratic: bool = False  # may run long_500k
    moe_path: str = "dispatch"  # "dispatch" (a2a) | "dense" (masked+psum)

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived quantities ----------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def attn_layer_ids(self) -> Tuple[int, ...]:
        """Indices (in the layer stack) that run attention."""
        if self.family in ("dense", "moe", "audio", "vlm"):
            return tuple(range(self.num_layers))
        if self.family == "ssm":
            return ()
        if self.family == "hybrid":
            p = self.attn_every
            return tuple(i for i in range(self.num_layers) if (i + 1) % p == 0)
        raise ValueError(self.family)

    # ---- parameter counting (for MODEL_FLOPS and memory budgeting) -------
    def param_count(self) -> int:
        """Exact parameter count of the constructed model."""
        d, v = self.d_model, self.padded_vocab
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += d * v  # lm head
        n += d  # final norm
        for i in range(self.num_layers):
            n += self._layer_params(i)
        if self.family == "hybrid" and self.attn_layer_ids():
            n += self._attn_params() + d  # one shared attention block + norm
        if self.family == "vlm":
            n += self.d_model * self.d_model  # patch-embedding projection stub
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def _mlp_params(self) -> int:
        return 3 * self.d_model * self.d_ff  # SwiGLU: w1, w3, w2

    def _ssm_params(self) -> int:
        d, di, ns = self.d_model, self.ssm_d_inner, self.ssm_state
        h = self.ssm_heads
        n = d * (2 * di + 2 * ns + h)  # in_proj → [x, z, B, C, dt]
        n += self.ssm_conv * (di + 2 * ns)  # causal depthwise conv on x,B,C
        n += h + h  # A_log, D (per head)
        n += di  # gated rmsnorm scale
        n += di * d  # out_proj
        return n

    def _layer_params(self, i: int) -> int:
        d = self.d_model
        if self.family in ("dense", "audio", "vlm"):
            return self._attn_params() + self._mlp_params() + 2 * d
        if self.family == "moe":
            router = d * self.n_experts
            experts = self.n_experts * 3 * d * self.d_ff
            return self._attn_params() + router + experts + 2 * d
        if self.family == "ssm":
            return self._ssm_params() + d
        if self.family == "hybrid":
            return self._ssm_params() + d  # shared attn counted once, above
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dead = self.num_layers * (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return self.param_count() - dead

    # ---- reduced config for CPU smoke tests ------------------------------
    def reduced(self) -> "ModelConfig":
        scale = {
            "num_layers": min(self.num_layers, 2),
            "d_model": 64,
            "n_heads": min(self.n_heads, 4) if self.n_heads else 0,
            "n_kv_heads": min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            "head_dim": 16 if self.n_heads else 0,
            "d_ff": 128,
            "vocab_size": 256,
            "n_experts": min(self.n_experts, 4) if self.n_experts else 0,
            "top_k": min(self.top_k, 2) if self.top_k else 0,
            "ssm_state": min(self.ssm_state, 16) if self.ssm_state else 0,
            "ssm_head_dim": 16 if self.ssm_state else 64,
            "ssm_chunk": 16 if self.ssm_state else 128,
            "attn_every": 2 if self.attn_every else 0,
            "n_frontend_tokens": 4 if self.n_frontend_tokens else 0,
            "attn_chunk": 32,
            "dtype": "float32",
            "name": self.name + "-smoke",
        }
        return replace(self, **scale)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs as _c  # noqa: F401  (ensure arch modules import)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(arch: str) -> list[Tuple[ModelConfig, ShapeConfig]]:
    """All (arch, shape) cells; long_500k only for sub-quadratic archs."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES.values():
        out.append((cfg, s))
    return out


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skipped: full-attention arch at 500k decode (see DESIGN.md §6.2)"
    return True, ""
