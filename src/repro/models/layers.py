"""Shared building blocks: norms, RoPE, SwiGLU, embeddings, chunked loss.

All functions are pure; parameters travel as (pytree, logical-axes-pytree)
pairs and activations are sharding-constrained through ``ShardingRules``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers


def normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def constrain(rules, x, axes):
    """Sharding constraint that degrades to identity without a mesh."""
    if rules is None:
        return x
    return rules.constrain(x, axes)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def gated_rms_norm(x, z, scale, eps):
    """Mamba2 output norm: rmsnorm(x * silu(z)) * scale."""
    return rms_norm(x * jax.nn.silu(z), scale, eps)


# ---------------------------------------------------------------------------
# RoPE


def rope_tables(positions, head_dim, theta):
    """positions [...,] → (cos, sin) tables [..., head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B, S, H, hd]; cos/sin [B, S, hd/2] or [S, hd/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :], sin[..., None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)


# ---------------------------------------------------------------------------
# MLP


def swiglu(x, w1, w3, w2, rules=None):
    """SwiGLU MLP; hidden dim sharded over 'model'."""
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, w1))
    h = h * jnp.einsum("...d,df->...f", x, w3)
    h = constrain(rules, h, (None,) * (h.ndim - 1) + ("mlp",))
    return jnp.einsum("...f,fd->...d", h, w2)


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d_model**-0.5, d_ff**-0.5
    params = {
        "w1": normal(k1, (d_model, d_ff), s_in, dtype),
        "w3": normal(k2, (d_model, d_ff), s_in, dtype),
        "w2": normal(k3, (d_ff, d_model), s_out, dtype),
    }
    axes = {
        "w1": ("embed", "mlp"),
        "w3": ("embed", "mlp"),
        "w2": ("mlp", "embed"),
    }
    return params, axes


# ---------------------------------------------------------------------------
# vocabulary / loss


def embed_tokens(embedding, tokens, rules=None):
    x = jnp.take(embedding, tokens, axis=0)
    return constrain(rules, x, ("batch", "seq", None))


def chunked_softmax_xent(
    x,
    lm_head,
    labels,
    mask,
    *,
    chunk: int = 256,
    rules=None,
):
    """Mean next-token cross-entropy without materializing [B,S,V].

    Scans over sequence chunks; logits live only per chunk (the activation-
    memory-honest formulation used for the dry-run memory analysis).
    Returns (mean_loss, total_weight).
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n = S // chunk

    # checkpointed: without this the scan's backward stacks each chunk's
    # one-hot/logits (≈ tokens·V bytes — OOM at 100k vocab); rematerializing
    # keeps only one chunk's transients alive during backward.
    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, args):
        xs, ls, ms = args  # [B, chunk, D], [B, chunk], [B, chunk]
        logits = jnp.einsum("bsd,dv->bsv", xs, lm_head).astype(jnp.float32)
        logits = constrain(rules, logits, ("batch", "seq", "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction instead of gather: partitions cleanly over a
        # vocab-sharded logits tensor (no cross-shard gather); bf16 one-hot
        # is exact (values are 0/1).
        onehot = (ls[..., None] == jnp.arange(logits.shape[-1])[None, None]).astype(
            jnp.bfloat16
        )
        tgt = jnp.sum(logits * onehot.astype(jnp.float32), axis=-1)
        nll = (lse - tgt) * ms
        return (carry[0] + nll.sum(), carry[1] + ms.sum()), None

    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, n, chunk).swapaxes(0, 1).astype(jnp.float32)
    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0), cnt


def init_norm(d, dtype):
    return jnp.ones((d,), dtype), ("embed",)
