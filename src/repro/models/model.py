"""Unified model: config → init / loss / prefill / decode for all families.

Layer stacks are scanned (``lax.scan`` over stacked [L, ...] parameters) so
the HLO stays compact at 48+ layers; remat policy wraps the scan body.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    chunked_softmax_xent,
    constrain,
    embed_tokens,
    init_mlp,
    normal,
    rms_norm,
    swiglu,
)
from repro.parallel import sharding as sharding_mod
from repro.parallel.sharding import ShardingRules

ATTN_FAMILIES = ("dense", "moe", "audio", "vlm")

# Families whose suffix prefill (prefill_with_prefix) is bitwise-identical
# to a cold prefill, so KV prefix reuse cannot change tokens. MoE is out
# (dispatch capacity depends on tokens-per-call, so suffix routing can
# drop different tokens), VLM is out (patch embeddings occupy cache rows
# that are not token-addressable). int8-KV rides along, but only
# *approximately*: the suffix path attends over DEQUANTIZED prefix K/V
# (≤1/254 relative error vs the fp rows the cold run saw), so deeper-
# layer suffix K/V and the first-token logits carry a quantization-level
# perturbation — greedy tokens agree unless an argmax near-tie flips
# (the differential test pins the tracked config; subsequent decode
# steps read the same quantized pool either way).
PREFIX_FAMILIES = ("dense", "audio")

# Families the speculative verify_step supports: the KV cache must be
# rewindable (truncating `len` un-commits rejected draft entries). SSM
# and hybrid are out — recurrent state cannot be truncated — and MoE is
# out because dispatch capacity depends on tokens-per-call, so a K+1
# token verify could route (and drop) differently than the sequential
# decode it must reproduce token-for-token.
SPEC_FAMILIES = ("dense", "audio", "vlm")

# Families whose prefill may be right-padded to a bucketed shape without
# changing tokens: position-addressable KV caches ignore pad rows (pad
# keys sit at positions strictly after every real query, so the causal
# mask removes them; pad KV rows past ``len`` are masked off and
# overwritten by later writes). SSM/hybrid are out — recurrent state
# integrates every position, pads included — and MoE is out because
# dispatch capacity depends on tokens-per-call, so padding changes which
# tokens get dropped.
PAD_PREFILL_FAMILIES = ("dense", "audio", "vlm")

# Families the chunked (incremental) prefill supports: one
# ``prefill_chunk`` call per ``chunk_size``-token slice of the prompt,
# riding the verify_step machinery (per-query causal masking at a data
# offset). Same exclusions as PREFIX_FAMILIES — chunk c>1 queries attend
# over cached earlier-chunk KV exactly like a suffix prefill over a
# prefix hit — plus VLM (patch embeddings are not token-chunkable).
CHUNKED_PREFILL_FAMILIES = ("dense", "audio")


def prefill_bucket(n: int, cap: Optional[int] = None) -> int:
    """Smallest power of two ≥ ``n`` (≥ 1), clamped to ``cap``.

    The prefill trace family: padding prompts (and prefill chunks) up to
    pow2 buckets means mixed-length open-loop workloads compile one
    prefill executable per bucket, not one per distinct length."""
    w = 1 << max(0, int(n) - 1).bit_length()
    return min(w, cap) if cap is not None else w

# baseline switch (launch.dryrun --legacy): pre-optimization decode scan
# slices the cache per layer via xs/ys, which writes a full layer-cache
# slice back per step (EXPERIMENTS.md §Perf #decode-cache)
LEGACY_CACHE_SCAN = False


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


class Model:
    """Functional model wrapper: all methods are pure and jit-friendly."""

    def __init__(self, cfg: ModelConfig, mesh=None, tp_axis=None, seq_axis=None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = ShardingRules(mesh, cfg) if mesh is not None else None
        # serving tensor parallelism: set on the LOCAL-view model built by
        # ``sharded_paged_step`` (cfg carries per-rank head counts, rules is
        # None so the kernel backend engages per-shard); attention gathers
        # head shards over this shard_map axis before the output projection
        self.tp_axis = tp_axis
        # serving kv-sequence split: also set by ``sharded_paged_step``.
        # Each rank holds a contiguous block-dim shard of the paged pool;
        # attention localizes the replicated block tables, computes flash
        # partials over owned positions only, and combines them with
        # collectives.distributed_softmax over this shard_map axis
        self.seq_axis = seq_axis

    # ------------------------------------------------------------------
    # parameters
    def init(self, key):
        cfg, dt = self.cfg, _dtype(self.cfg)
        keys = jax.random.split(key, cfg.num_layers + 8)
        V, D = cfg.padded_vocab, cfg.d_model
        params: dict[str, Any] = {
            "embed": normal(keys[0], (V, D), D**-0.5, dt),
            "final_norm": jnp.ones((D,), dt),
        }
        axes: dict[str, Any] = {
            "embed": ("vocab", "embed"),
            "final_norm": ("embed",),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = normal(keys[1], (D, V), D**-0.5, dt)
            axes["lm_head"] = ("embed", "vocab")
        if cfg.family == "vlm":
            params["patch_proj"] = normal(keys[2], (D, D), D**-0.5, dt)
            axes["patch_proj"] = (None, "embed")

        lp, la = [], None
        for i in range(cfg.num_layers):
            p, a = self._init_layer(keys[3 + i], i)
            lp.append(p)
            la = a
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *lp)
        axes["layers"] = jax.tree.map(
            lambda ax: ("layers",) + ax, la, is_leaf=lambda x: isinstance(x, tuple)
        )
        if cfg.family == "hybrid":
            p, a = self._init_shared_block(keys[2])
            params["shared"], axes["shared"] = p, a
        return params, axes

    def _init_layer(self, key, i):
        cfg, dt = self.cfg, _dtype(self.cfg)
        k1, k2, k3 = jax.random.split(key, 3)
        if cfg.family in ("ssm", "hybrid"):
            p, a = ssm_mod.init_ssm(k1, cfg, dt)
            return (
                {"ssm": p, "norm": jnp.ones((cfg.d_model,), dt)},
                {"ssm": a, "norm": ("embed",)},
            )
        ap, aa = attn.init_attention(k1, cfg, dt)
        p = {
            "attn": ap,
            "attn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp_norm": jnp.ones((cfg.d_model,), dt),
        }
        a = {"attn": aa, "attn_norm": ("embed",), "mlp_norm": ("embed",)}
        if cfg.family == "moe":
            p["moe"], a["moe"] = moe_mod.init_moe(k2, cfg, dt)
        else:
            p["mlp"], a["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dt)
        return p, a

    def _init_shared_block(self, key):
        cfg, dt = self.cfg, _dtype(self.cfg)
        k1, k2 = jax.random.split(key)
        ap, aa = attn.init_attention(k1, cfg, dt)
        mp, ma = init_mlp(k2, cfg.d_model, cfg.d_ff, dt)
        p = {
            "attn": ap,
            "mlp": mp,
            "attn_norm": jnp.ones((cfg.d_model,), dt),
            "mlp_norm": jnp.ones((cfg.d_model,), dt),
        }
        a = {"attn": aa, "mlp": ma, "attn_norm": ("embed",), "mlp_norm": ("embed",)}
        return p, a

    def abstract_params(self):
        """(ShapeDtypeStruct tree, logical-axes tree) without allocation."""
        box = {}

        def f(k):
            p, a = self.init(k)
            box["axes"] = a
            return p

        shapes = jax.eval_shape(f, jax.random.key(0))
        return shapes, box["axes"]

    # ------------------------------------------------------------------
    # shared layer bodies
    def _dense_layer(
        self, x, lp, path, positions=None, cache=None, cache_len=None,
        prefix_kv=None, backend=None,
    ):
        cfg, rules = self.cfg, self.rules
        h, new_kv = attn.attention_block(
            lp["attn"],
            rms_norm(x, lp["attn_norm"], cfg.norm_eps),
            cfg=cfg,
            rules=rules,
            positions=positions,
            cache=cache,
            cache_len=cache_len,
            prefix_kv=prefix_kv,
            backend=backend,
            tp_axis=self.tp_axis,
            seq_axis=self.seq_axis,
        )
        x = x + h
        hin = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        if cfg.family == "moe":
            h, aux = moe_mod.moe_block(hin, lp["moe"], cfg, rules, path=path)
        else:
            h, aux = swiglu(hin, lp["mlp"]["w1"], lp["mlp"]["w3"], lp["mlp"]["w2"], rules), 0.0
        return x + h, aux, new_kv

    def _ssm_layer(self, x, lp, state=None, want_state=False):
        cfg, rules = self.cfg, self.rules
        h, new_state = ssm_mod.ssm_block(
            lp["ssm"],
            rms_norm(x, lp["norm"], cfg.norm_eps),
            cfg,
            rules,
            state=state,
            want_state=want_state,
        )
        return x + h, new_state

    # ------------------------------------------------------------------
    # forward (train / prefill)
    def forward(self, params, tokens, patch_embeds=None, want_cache=False):
        """tokens [B,S'] → final hidden [B,S,D] (+ per-layer KV if asked)."""
        cfg, rules = self.cfg, self.rules
        x = embed_tokens(params["embed"], tokens, rules)
        if cfg.family == "vlm":
            pe = jnp.einsum("bpd,de->bpe", patch_embeds.astype(x.dtype), params["patch_proj"])
            x = jnp.concatenate([pe, x], axis=1)
        x = constrain(rules, x, ("batch", "seq", None))

        if cfg.family in ATTN_FAMILIES:
            x, aux, caches = self._forward_attn_stack(params, x, want_cache)
        elif cfg.family == "ssm":
            x, caches = self._forward_ssm_stack(params, x, want_cache)
            aux = 0.0
        elif cfg.family == "hybrid":
            x, caches = self._forward_hybrid_stack(params, x, want_cache)
            aux = 0.0
        else:
            raise ValueError(cfg.family)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, caches

    def _maybe_remat(self, fn):
        if self.cfg.remat == "none":
            return fn
        if self.cfg.remat == "full":
            policy = jax.checkpoint_policies.nothing_saveable
        else:
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)

    def _forward_attn_stack(self, params, x, want_cache):
        path = "dispatch"

        def body(carry, lp):
            x, aux = carry
            x, a, kv = self._dense_layer(x, lp, path)
            ys = kv if want_cache else None
            return (x, aux + a), ys

        (x, aux), caches = jax.lax.scan(
            self._maybe_remat(body), (x, 0.0), params["layers"]
        )
        return x, aux, caches

    def _forward_ssm_stack(self, params, x, want_state=False):
        def body(x, lp):
            x, st = self._ssm_layer(x, lp, want_state=want_state)
            return x, st

        x, sts = jax.lax.scan(self._maybe_remat(body), x, params["layers"])
        return x, sts

    def _hybrid_grouped_params(self, params):
        cfg = self.cfg
        G = cfg.num_layers // cfg.attn_every
        return jax.tree.map(
            lambda p: p.reshape((G, cfg.attn_every) + p.shape[1:]), params["layers"]
        )

    def _forward_hybrid_stack(self, params, x, want_cache):
        cfg = self.cfg
        shared = params["shared"]

        def group(carry, glp):
            x = carry

            def inner(x, lp):
                x, st = self._ssm_layer(x, lp, want_state=want_cache)
                return x, st

            x, sts = jax.lax.scan(inner, x, glp)
            h, kv = attn.attention_block(
                shared["attn"],
                rms_norm(x, shared["attn_norm"], cfg.norm_eps),
                cfg=cfg,
                rules=self.rules,
            )
            x = x + h
            x = x + swiglu(
                rms_norm(x, shared["mlp_norm"], cfg.norm_eps),
                shared["mlp"]["w1"],
                shared["mlp"]["w3"],
                shared["mlp"]["w2"],
                self.rules,
            )
            return x, ((kv, sts) if want_cache else None)

        x, caches = jax.lax.scan(
            self._maybe_remat(group), x, self._hybrid_grouped_params(params)
        )
        return x, caches

    # ------------------------------------------------------------------
    # losses / steps
    def loss(self, params, batch):
        """batch: tokens [B,S], labels [B,S], mask [B,S] (+patch_embeds)."""
        cfg = self.cfg
        x, aux, _ = self.forward(
            params, batch["tokens"], patch_embeds=batch.get("patch_embeds")
        )
        if cfg.family == "vlm":
            # hidden includes prepended patches; they predict nothing
            n = cfg.n_frontend_tokens
            x = x[:, n:, :]
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        ce, cnt = chunked_softmax_xent(
            x, head, batch["labels"], batch["mask"], rules=self.rules
        )
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux, "tokens": cnt}

    # ------------------------------------------------------------------
    # decode
    def jit_step(self, name: str, backend=None):
        """``jax.jit`` of one decode-family step (``decode_step``,
        ``verify_step``, ``decode_step_paged``, ``verify_step_paged``)
        with the attention backend resolved and bound STATICALLY.
        The single place backend binding happens (engine, scheduler,
        and draft streams all build their step fns here), so the
        registry is consulted before tracing — a later registry change
        can never silently retarget an existing trace (DESIGN.md §4)."""
        backend = kernel_ops.resolve_attention_backend(backend)
        return jax.jit(functools.partial(getattr(self, name), backend=backend))

    def paged_pool_specs(self, axis="model", seq_axis=None):
        """PartitionSpecs for the block-pool leaves under a serving mesh
        (DESIGN.md §5): KV (and scale) leaves shard on the kv-head axis
        over ``axis`` (head TP) and/or on the block dim over ``seq_axis``
        (kv-sequence split); the layer/offset axes are physical storage
        walked identically by every rank. Block tables and lengths are
        data, not pool leaves — they stay replicated (seq ranks localize
        them in-body). Delegates to ``parallel.sharding.paged_pool_specs``,
        where the seq rule lives."""
        return sharding_mod.paged_pool_specs(
            axis, seq_axis, quantized=bool(self.cfg.kv_quant)
        )

    def sharded_paged_step(
        self, name: str, mesh, backend=None, axis="model", seq_axis="seq"
    ):
        """``jit_step`` counterpart for mesh-sharded paged serving:
        ``jit(shard_map(...))`` of ``decode_step_paged`` /
        ``verify_step_paged`` over a 1D or 2D serving mesh. Everything
        but the pool (params, block tables, lengths, tokens, logits)
        stays replicated.

        Head split (mesh axis ``axis``, PR 7 — bitwise): each rank
        slices its contiguous head block out of the replicated q/k/v
        projections (rank r owns q heads [r·H/P, (r+1)·H/P) and the
        matching kv groups — GQA groups never straddle ranks) and runs
        the UNSHARDED step body through a local-view model whose cfg
        carries the per-rank head counts. Head shards are gathered back
        before the (replicated) output projection inside
        ``attention_block`` — no cross-rank float reduction, so the
        logits are bitwise single-device.

        Sequence split (mesh axis ``seq_axis`` — rounding-level): the
        pool's block dim is partitioned so each rank owns a contiguous
        range of physical blocks (``serve/kv_cache.py`` lays slots out
        with one scratch block per shard). Attention localizes the
        replicated block tables in-body (unowned entries → the rank's
        scratch slot), computes flash running-form partials (m, l, acc)
        over owned positions only, and combines them with
        ``collectives.distributed_softmax`` over ``seq_axis`` — a
        cross-rank float reduction, hence tokens match single-device to
        rounding, not bitwise (the tolerance differential lane,
        DESIGN.md §5). Both splits compose on a 2D ``(axis, seq_axis)``
        mesh: per-rank partials cover (local heads × owned positions);
        the seq combine completes each head's softmax, then the head
        gather reassembles the full head set. Tables and lengths remain
        data, so the single-trace / no-retrace invariants of
        ``jit_step`` carry over unchanged."""
        backend = kernel_ops.resolve_attention_backend(backend, mesh=mesh)
        cfg = self.cfg
        tp = mesh.shape.get(axis, 1) if axis else 1
        sp = mesh.shape.get(seq_axis, 1) if seq_axis else 1
        if tp == 1 and sp == 1:
            return self.jit_step(name, backend=backend)
        if tp > 1 and (cfg.n_kv_heads % tp or cfg.n_heads % tp):
            raise ValueError(
                f"n_kv_heads={cfg.n_kv_heads}/n_heads={cfg.n_heads} do not "
                f"divide mesh axis {axis!r} (size {tp}); ShardingRules "
                "dropped the head mapping — serve replicated instead"
            )
        from repro.compat import shard_map
        from jax.sharding import PartitionSpec as P

        h_loc, kv_loc, hd = cfg.n_heads // tp, cfg.n_kv_heads // tp, cfg.head_dim
        local = Model(
            dataclasses.replace(cfg, n_heads=h_loc, n_kv_heads=kv_loc),
            tp_axis=axis if tp > 1 else None,
            seq_axis=seq_axis if sp > 1 else None,
        )
        step = getattr(local, name)

        def slice_heads(attn_p):
            r = jax.lax.axis_index(axis)

            def sl(w, n_loc):
                if w.ndim == 3:  # flat-TP [L, d, h·hd]: heads are
                    # flattened h-major, so a head block is a contiguous
                    # column range
                    return jax.lax.dynamic_slice_in_dim(
                        w, r * n_loc * hd, n_loc * hd, axis=2
                    )
                return jax.lax.dynamic_slice_in_dim(w, r * n_loc, n_loc, axis=2)

            return dict(
                attn_p,
                wq=sl(attn_p["wq"], h_loc),
                wk=sl(attn_p["wk"], kv_loc),
                wv=sl(attn_p["wv"], kv_loc),
            )  # wo stays full: the output projection runs on gathered heads

        def body(params, pool, block_tables, cache_len, tokens):
            layers = params["layers"]
            if tp > 1:
                layers = dict(layers, attn=slice_heads(layers["attn"]))
            return step(
                dict(params, layers=layers),
                pool,
                block_tables,
                cache_len,
                tokens,
                backend=backend,
            )

        pool_specs = self.paged_pool_specs(
            axis if tp > 1 else None, seq_axis if sp > 1 else None
        )
        fn = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), pool_specs, P(), P(), P()),
            out_specs=(P(), pool_specs),
            check_vma=False,
        )
        return jax.jit(fn)

    def init_cache(self, batch, max_seq, dtype=None):
        cfg = self.cfg
        dt = dtype or _dtype(cfg)
        KV, hd = cfg.n_kv_heads, cfg.head_dim

        def kv_cache(n):
            if cfg.kv_quant:
                return {
                    "k": jnp.zeros((n, batch, max_seq, KV, hd), jnp.int8),
                    "v": jnp.zeros((n, batch, max_seq, KV, hd), jnp.int8),
                    "k_scale": jnp.zeros((n, batch, max_seq, KV), jnp.bfloat16),
                    "v_scale": jnp.zeros((n, batch, max_seq, KV), jnp.bfloat16),
                }
            return {
                "k": jnp.zeros((n, batch, max_seq, KV, hd), dt),
                "v": jnp.zeros((n, batch, max_seq, KV, hd), dt),
            }

        cache: dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32)}
        if cfg.family in ATTN_FAMILIES:
            cache.update(kv_cache(cfg.num_layers))
        elif cfg.family == "ssm":
            st = ssm_mod.init_ssm_state(cfg, batch, dt)
            cache["ssm_state"] = jax.tree.map(
                lambda s: jnp.broadcast_to(s[None], (cfg.num_layers,) + s.shape), st
            )
        elif cfg.family == "hybrid":
            G = cfg.num_layers // cfg.attn_every
            st = ssm_mod.init_ssm_state(cfg, batch, dt)
            cache["ssm_state"] = jax.tree.map(
                lambda s: jnp.broadcast_to(s[None], (cfg.num_layers,) + s.shape), st
            )
            cache.update(kv_cache(G))
        return cache

    def cache_axes(self, cache):
        """Logical axes for every cache leaf (for dry-run shardings)."""

        def leaf_axes(path, leaf):
            names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if "k_scale" in names or "v_scale" in names:
                return (None, "batch", "kv_seq", "kv_heads")
            if "k" in names or "v" in names:
                return (None, "batch", "kv_seq", "kv_heads", None)
            if "ssm" in names:
                return (None, "batch", "ssm_heads", None, None)
            if "conv_x" in names:
                return (None, "batch", None, "ssm_inner")
            if "conv_B" in names or "conv_C" in names:
                return (None, "batch", None, None)
            if "len" in names:
                return (None,)
            return (None,) * leaf.ndim

        return jax.tree_util.tree_map_with_path(leaf_axes, cache)

    def cache_batch_axes(self, cache):
        """Per-leaf batch-axis index of the decode cache, derived from the
        ``cache_axes`` logical names (leaves without an explicit 'batch'
        axis — ``len`` — are batch-leading). The serving slot pool and the
        decode Region both slice per-request views through this, so slot
        logic is family-agnostic."""
        return jax.tree.map(
            lambda t: t.index("batch") if "batch" in t else 0,
            self.cache_axes(cache),
            is_leaf=lambda x: isinstance(x, tuple),
        )

    def read_cache_slot(self, pool, slot):
        """Batch slot ``slot`` of a pooled decode cache as a batch=1 cache."""
        return jax.tree.map(
            lambda leaf, ax: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax),
            pool,
            self.cache_batch_axes(pool),
        )

    def write_cache_slot(self, pool, slot_cache, slot):
        """Write a batch=1 cache (e.g. one request's prefill output) into
        batch slot ``slot`` of a pooled decode cache; non-batch dims must
        match the pool's (same ``max_seq``)."""
        return jax.tree.map(
            lambda leaf, sl, ax: jax.lax.dynamic_update_slice_in_dim(
                leaf, sl.astype(leaf.dtype), slot, axis=ax
            ),
            pool,
            slot_cache,
            self.cache_batch_axes(pool),
        )

    # ------------------------------------------------------------------
    # block-paged decode cache (serve/kv_cache.PagedKVCache)
    def init_paged_cache(self, num_blocks, block_size, dtype=None):
        """Physical block pool: ``num_blocks`` blocks of ``block_size``
        tokens each, laid out exactly like a decode cache with
        batch=num_blocks and max_seq=block_size. Attention families only
        — SSM state has no sequence axis to page. Per-row lengths live
        with the block tables (PagedKVCache), not in the pool."""
        if self.cfg.family not in ATTN_FAMILIES:
            raise ValueError(
                f"paged KV cache needs an attention family, got {self.cfg.family!r}"
            )
        pool = self.init_cache(num_blocks, block_size, dtype=dtype)
        pool.pop("len")
        return pool

    def paged_view(self, pool, block_tables):
        """Dense [L, B, MB·BS, ...] per-row caches gathered from the block
        pool through ``block_tables`` [B, MB] — the fixed-shape read side
        of paged decode."""
        return {
            name: attn.gather_block_rows(leaf, block_tables)
            for name, leaf in pool.items()
        }

    def decode_step_paged(
        self, params, pool, block_tables, cache_len, tokens, *, backend=None
    ):
        """One decode token over a block-paged KV cache.

        Kernel backends run the layer scan directly over the pool: each
        layer scatters the new token's KV rows into the row's tail block
        and attends *through the block tables* inside the Pallas kernel
        — no dense materialization (DESIGN.md §4). The reference backend
        keeps the original differential route: gather each row's K/V
        through its table into the fixed-shape dense view
        (``gather_block_rows``), run the ordinary ``decode_step``
        (identical numerics), scatter the appended token back. Shared
        prefix blocks are never a write target (the scheduler only
        shares immutable full-prompt blocks), so the scatter touches
        exclusively-owned blocks only. ``block_tables`` and
        ``cache_len`` are data, not shape: one jit trace serves any
        block layout and live set.

        Under the kv-sequence split (``self.seq_axis`` set on the
        per-rank model inside ``sharded_paged_step``) the reference
        backend also runs the layer scan: the dense differential route
        gathers through *global* tables, which cannot address a rank's
        local pool shard — the dict-cache path localizes them and
        combines per-rank flash partials instead (DESIGN.md §5)."""
        backend = kernel_ops.resolve_attention_backend(backend)
        if backend != "reference" or self.seq_axis is not None:
            logits, new_pool = self._step_paged_kernel(
                params, pool, block_tables, cache_len, tokens, backend
            )
            return logits[:, 0], new_pool
        bs = pool["k"].shape[2]
        dense = self.paged_view(pool, block_tables)
        logits, new_dense = self.decode_step(
            params, dict(dense, len=cache_len), tokens, backend="reference"
        )
        bid, off = attn.block_write_positions(block_tables, cache_len, 1, bs)
        bid, off = bid[:, 0], off[:, 0]
        new_pool = {}
        for name, leaf in pool.items():
            nd = new_dense[name]  # [L, B, MB·BS, ...]
            idx = cache_len.reshape((1, -1, 1) + (1,) * (nd.ndim - 3))
            token_rows = jnp.take_along_axis(nd, idx, axis=2)[:, :, 0]
            new_pool[name] = attn.scatter_block_token(leaf, token_rows, bid, off)
        return logits, new_pool

    def _step_paged_kernel(self, params, pool, block_tables, cache_len, tokens, backend):
        """Shared decode/verify layer scan over the block pool itself:
        the per-layer cache is the dict form ``attention_block`` pages
        through (tail-block scatter + table-walking kernel attention).
        tokens [B,T] (T=1 decode, K+1 verify) → (logits [B,T,V], new
        pool). Tables and lengths stay data — one trace per (T, backend)."""
        cfg, rules = self.cfg, self.rules
        B, T = tokens.shape
        x = embed_tokens(params["embed"], tokens, rules)
        x = constrain(rules, x, ("batch", "seq", None))
        positions = cache_len[:, None] + jnp.arange(T)[None, :]
        names = ("k", "k_scale", "v", "v_scale") if cfg.kv_quant else ("k", "v")

        def body(carry, xs):
            x, leaves = carry
            lp, li = xs
            cache = dict(zip(names, leaves), tables=block_tables, li=li)
            xo, _, new_leaves = self._dense_layer(
                x, lp, "dense", positions=positions, cache=cache,
                cache_len=cache_len, backend=backend,
            )
            return (xo, new_leaves), None

        (x, leaves), _ = jax.lax.scan(
            body,
            (x, tuple(pool[n] for n in names)),
            (params["layers"], jnp.arange(cfg.num_layers)),
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("btd,dv->btv", x, head)
        logits = constrain(rules, logits, ("batch", None, "vocab"))
        return logits, dict(zip(names, leaves))

    # ------------------------------------------------------------------
    # speculative verify (serve/speculative.py)
    def verify_step(self, params, cache, tokens, *, backend=None):
        """tokens [B,T] (pending token + T-1 draft tokens) → (logits
        [B,T,V], new cache with len += T). One speculative verify.

        The draft stream's proposals run as ONE forward over the decode
        cache: position t's logits predict the token after
        ``tokens[:, t]``, so greedy acceptance compares each draft
        against the previous position's argmax. T is static (one jit
        trace per speculation depth K = T-1) while acceptance counts
        stay data — the caller rewinds rejected tail entries afterwards
        with ``truncate_row`` (stale KV rows past the committed length
        are masked off by ``len`` and overwritten by later writes, so
        only the lengths rewind). ``SPEC_FAMILIES`` only: rewinding
        needs a length-addressed cache, and MoE token-count-dependent
        routing would break greedy equivalence."""
        cfg, rules = self.cfg, self.rules
        if cfg.family not in SPEC_FAMILIES:
            raise ValueError(
                f"verify_step is only greedy-equivalent for {SPEC_FAMILIES}, "
                f"got {cfg.family!r} (SSM state cannot rewind; MoE capacity "
                "routing depends on tokens-per-call)"
            )
        B, T = tokens.shape
        x = embed_tokens(params["embed"], tokens, rules)
        x = constrain(rules, x, ("batch", "seq", None))
        positions = cache["len"][:, None] + jnp.arange(T)[None, :]

        if cfg.kv_quant:

            def body_q(carry, xs):
                x, ks, kss, vs, vss = carry
                lp, li = xs
                xo, _, (ks, kss, vs, vss) = self._dense_layer(
                    x, lp, "dense", positions=positions,
                    cache=(ks, kss, vs, vss, li), cache_len=cache["len"],
                    backend=backend,
                )
                return (xo, ks, kss, vs, vss), None

            (x, ks, kss, vs, vss), _ = jax.lax.scan(
                body_q,
                (x, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"]),
                (params["layers"], jnp.arange(cfg.num_layers)),
            )
            new_cache = {"k": ks, "k_scale": kss, "v": vs, "v_scale": vss,
                         "len": cache["len"] + T}
        else:

            def body(carry, xs):
                x, ks, vs = carry
                lp, li = xs
                xo, _, (ks, vs) = self._dense_layer(
                    x, lp, "dense", positions=positions,
                    cache=(ks, vs, li), cache_len=cache["len"],
                    backend=backend,
                )
                return (xo, ks, vs), None

            (x, ks, vs), _ = jax.lax.scan(
                body,
                (x, cache["k"], cache["v"]),
                (params["layers"], jnp.arange(cfg.num_layers)),
            )
            new_cache = {"k": ks, "v": vs, "len": cache["len"] + T}

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("btd,dv->btv", x, head)
        logits = constrain(rules, logits, ("batch", None, "vocab"))
        return logits, new_cache

    def verify_step_paged(
        self, params, pool, block_tables, cache_len, tokens, *, backend=None
    ):
        """Speculative verify over the block-paged cache.

        Kernel backends run the same table-walking layer scan as
        ``decode_step_paged`` with T = K+1 queries (the kernel's verify
        variant; the T positions may cross a block boundary — the
        scheduler pre-claims every reachable tail block via
        ``ensure_tail_n`` before calling). The reference backend
        gathers each row's dense view through its block table, runs the
        ordinary ``verify_step`` (identical numerics), then scatters the
        T new per-token KV rows back through the tables
        (``scatter_block_tokens``). Dead rows' tables point at the null
        block, so their writes land in scratch. Tables, lengths, and
        acceptance are data: one trace per depth. Like
        ``decode_step_paged``, the kv-sequence split forces the layer
        scan for the reference backend too (global tables cannot
        address a rank's local pool shard)."""
        backend = kernel_ops.resolve_attention_backend(backend)
        if backend != "reference" or self.seq_axis is not None:
            if self.cfg.family not in SPEC_FAMILIES:
                raise ValueError(
                    f"verify_step is only greedy-equivalent for {SPEC_FAMILIES}, "
                    f"got {self.cfg.family!r}"
                )
            return self._step_paged_kernel(
                params, pool, block_tables, cache_len, tokens, backend
            )
        bs = pool["k"].shape[2]
        T = tokens.shape[1]
        dense = self.paged_view(pool, block_tables)
        logits, new_dense = self.verify_step(
            params, dict(dense, len=cache_len), tokens, backend="reference"
        )
        bid, off = attn.block_write_positions(block_tables, cache_len, T, bs)
        pos = cache_len[:, None] + jnp.arange(T)[None, :]  # [B, T]
        new_pool = {}
        for name, leaf in pool.items():
            nd = new_dense[name]  # [L, B, MB·BS, ...]
            idx = pos.reshape((1,) + pos.shape + (1,) * (nd.ndim - 3))
            token_rows = jnp.take_along_axis(nd, idx, axis=2)  # [L, B, T, ...]
            new_pool[name] = attn.scatter_block_tokens(leaf, token_rows, bid, off)
        return logits, new_pool

    def decode_step(self, params, cache, tokens, *, backend=None):
        """tokens [B,1] → (logits [B,V], new cache). One new token.
        ``backend`` picks the cached-attention backend (DESIGN.md §4);
        None resolves the ops-registry default at trace time."""
        cfg, rules = self.cfg, self.rules
        B = tokens.shape[0]
        x = embed_tokens(params["embed"], tokens, rules)
        x = constrain(rules, x, ("batch", "seq", None))
        positions = cache["len"][:, None]
        aux = 0.0

        if cfg.family in ATTN_FAMILIES and LEGACY_CACHE_SCAN:

            def body_legacy(x, xs):
                lp, kc, vc = xs
                xo, _, (kc, vc) = self._dense_layer(
                    x, lp, "dense", positions=positions,
                    cache=(kc, vc), cache_len=cache["len"],
                    backend=backend,
                )
                return xo, (kc, vc)

            x, (ks, vs) = jax.lax.scan(
                body_legacy, x, (params["layers"], cache["k"], cache["v"])
            )
            new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
        elif cfg.family in ATTN_FAMILIES and cfg.kv_quant:

            def body_q(carry, xs):
                x, ks, kss, vs, vss = carry
                lp, li = xs
                xo, _, (ks, kss, vs, vss) = self._dense_layer(
                    x, lp, "dense", positions=positions,
                    cache=(ks, kss, vs, vss, li), cache_len=cache["len"],
                    backend=backend,
                )
                return (xo, ks, kss, vs, vss), None

            (x, ks, kss, vs, vss), _ = jax.lax.scan(
                body_q,
                (x, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"]),
                (params["layers"], jnp.arange(cfg.num_layers)),
            )
            new_cache = {"k": ks, "k_scale": kss, "v": vs, "v_scale": vss,
                         "len": cache["len"] + 1}
        elif cfg.family in ATTN_FAMILIES:
            # the cache STACK rides in the carry: per-step writes are one
            # token, and donation aliases the whole stack in place
            def body(carry, xs):
                x, ks, vs = carry
                lp, li = xs
                xo, _, (ks, vs) = self._dense_layer(
                    x, lp, "dense", positions=positions,
                    cache=(ks, vs, li), cache_len=cache["len"],
                    backend=backend,
                )
                return (xo, ks, vs), None

            (x, ks, vs), _ = jax.lax.scan(
                body,
                (x, cache["k"], cache["v"]),
                (params["layers"], jnp.arange(cfg.num_layers)),
            )
            new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
        elif cfg.family == "ssm":

            def body(x, xs):
                lp, st = xs
                x, new_st = self._ssm_layer(x, lp, state=st)
                return x, new_st

            x, sts = jax.lax.scan(body, x, (params["layers"], cache["ssm_state"]))
            new_cache = {"ssm_state": sts, "len": cache["len"] + 1}
        elif cfg.family == "hybrid":
            x, new_cache = self._hybrid_decode(params, x, cache, positions, backend)
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
        logits = constrain(rules, logits, ("batch", "vocab"))
        return logits, new_cache

    def _hybrid_decode(self, params, x, cache, positions, backend=None):
        cfg = self.cfg
        shared = params["shared"]
        glp = self._hybrid_grouped_params(params)
        G = cfg.num_layers // cfg.attn_every
        sts = jax.tree.map(
            lambda s: s.reshape((G, cfg.attn_every) + s.shape[1:]),
            cache["ssm_state"],
        )

        if LEGACY_CACHE_SCAN:
            return self._hybrid_decode_legacy(params, x, cache, positions, shared, glp, sts, G)

        quant = cfg.kv_quant

        def group(carry, xs):
            x, kv = carry
            lp, st, gi = xs

            def inner(x, xs2):
                lp2, st2 = xs2
                x, new_st2 = self._ssm_layer(x, lp2, state=st2)
                return x, new_st2

            x, new_st = jax.lax.scan(inner, x, (lp, st))
            h, kv = attn.attention_block(
                shared["attn"],
                rms_norm(x, shared["attn_norm"], cfg.norm_eps),
                cfg=cfg,
                rules=self.rules,
                positions=positions,
                cache=kv + (gi,),  # in-place token write into the stack
                cache_len=cache["len"],
                backend=backend,
            )
            x = x + h
            x = x + swiglu(
                rms_norm(x, shared["mlp_norm"], cfg.norm_eps),
                shared["mlp"]["w1"],
                shared["mlp"]["w3"],
                shared["mlp"]["w2"],
                self.rules,
            )
            return (x, kv), new_st

        kv0 = (
            (cache["k"], cache["k_scale"], cache["v"], cache["v_scale"])
            if quant
            else (cache["k"], cache["v"])
        )
        (x, kv), new_sts = jax.lax.scan(group, (x, kv0), (glp, sts, jnp.arange(G)))
        new_sts = jax.tree.map(
            lambda s: s.reshape((cfg.num_layers,) + s.shape[2:]), new_sts
        )
        out_cache = {"ssm_state": new_sts, "len": cache["len"] + 1}
        if quant:
            out_cache.update(k=kv[0], k_scale=kv[1], v=kv[2], v_scale=kv[3])
        else:
            out_cache.update(k=kv[0], v=kv[1])
        return x, out_cache

    def _hybrid_decode_legacy(self, params, x, cache, positions, shared, glp, sts, G):
        """Pre-optimization hybrid decode (baseline measurement only)."""
        cfg = self.cfg

        def group(x, xs):
            lp, st, kc, vc = xs

            def inner(x, xs2):
                lp2, st2 = xs2
                x, new_st2 = self._ssm_layer(x, lp2, state=st2)
                return x, new_st2

            x, new_st = jax.lax.scan(inner, x, (lp, st))
            h, (kc, vc) = attn.attention_block(
                shared["attn"],
                rms_norm(x, shared["attn_norm"], cfg.norm_eps),
                cfg=cfg, rules=self.rules, positions=positions,
                cache=(kc, vc), cache_len=cache["len"],
            )
            x = x + h
            x = x + swiglu(
                rms_norm(x, shared["mlp_norm"], cfg.norm_eps),
                shared["mlp"]["w1"], shared["mlp"]["w3"], shared["mlp"]["w2"],
                self.rules,
            )
            return x, (new_st, kc, vc)

        x, (new_sts, ks, vs) = jax.lax.scan(group, x, (glp, sts, cache["k"], cache["v"]))
        new_sts = jax.tree.map(
            lambda s: s.reshape((cfg.num_layers,) + s.shape[2:]), new_sts
        )
        return x, {"ssm_state": new_sts, "k": ks, "v": vs, "len": cache["len"] + 1}

    # ------------------------------------------------------------------
    def prefill(self, params, tokens, max_seq, patch_embeds=None, prompt_len=None):
        """Run the prompt, return (next-token logits [B,V], filled cache).

        ``prompt_len`` [B] (optional) marks per-row effective prompt
        lengths when ``tokens`` is right-padded to a pow2 bucket
        (``prefill_bucket``): logits come from each row's last *real*
        token and ``cache["len"]`` becomes a per-row vector, so pad
        rows never commit. Pad keys sit at positions strictly after
        every real query, so causal masking makes the padded run
        bitwise-identical to the unpadded one for
        ``PAD_PREFILL_FAMILIES``."""
        cfg = self.cfg
        if prompt_len is not None and cfg.family not in PAD_PREFILL_FAMILIES:
            raise ValueError(
                f"padded prefill is only token-identical for "
                f"{PAD_PREFILL_FAMILIES}, got {cfg.family!r} (recurrent state "
                "integrates pad positions; MoE capacity depends on tokens-per-call)"
            )
        x, _, caches = self.forward(
            params, tokens, patch_embeds=patch_embeds, want_cache=True
        )
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        B, S = x.shape[0], x.shape[1]
        if prompt_len is None:
            x_last = x[:, -1]
        else:
            n_lead = S - tokens.shape[1]  # VLM patch rows lead the tokens
            idx = n_lead + jnp.asarray(prompt_len, jnp.int32) - 1
            x_last = x[jnp.arange(B), idx]
        logits = jnp.einsum("bd,dv->bv", x_last, head)
        cache = self.init_cache(B, max_seq)

        def fill_kv(cache, k, v):
            if cfg.kv_quant:
                kq, ks = attn.quantize_kv(k)
                vq, vs = attn.quantize_kv(v)
                for name, val, ax in (
                    ("k", kq, 2), ("k_scale", ks, 2), ("v", vq, 2), ("v_scale", vs, 2),
                ):
                    cache[name] = jax.lax.dynamic_update_slice_in_dim(
                        cache[name], val, 0, axis=ax
                    )
            else:
                cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=2)
                cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=2)
            return cache

        if cfg.family in ATTN_FAMILIES:
            k, v = caches  # [L,B,S,KV,hd]
            cache = fill_kv(cache, k, v)
        elif cfg.family == "ssm":
            cache["ssm_state"] = caches
        elif cfg.family == "hybrid":
            (k, v), sts = caches  # kv [G,B,S,KV,hd]; sts [G,per,...]
            cache = fill_kv(cache, k, v)
            cache["ssm_state"] = jax.tree.map(
                lambda s: s.reshape((cfg.num_layers,) + s.shape[2:]), sts
            )
        if prompt_len is None:
            cache["len"] = jnp.full_like(cache["len"], S)
        else:
            n_lead = S - tokens.shape[1]
            cache["len"] = (n_lead + jnp.asarray(prompt_len, jnp.int32)).astype(
                cache["len"].dtype
            )
        return logits, cache

    def prefill_with_prefix(
        self, params, tokens, prefix_k, prefix_v, max_seq, suffix_len=None
    ):
        """Suffix prefill over an already-cached prompt prefix.

        ``tokens`` [B, Ssuf] are the prompt tokens *after* the cached
        prefix; ``prefix_k``/``prefix_v`` [L, B, h, KV, hd] are the
        prefix's post-RoPE KV rows (as gathered from the paged pool).
        Returns (next-token logits [B, V], dense cache holding the full
        prefix+suffix KV, len = h + Ssuf). Because per-query flash
        accumulation never depends on which other query rows run, the
        suffix comes out bitwise-identical to a cold full-prompt
        ``prefill`` for dense/audio families — at the cost of the suffix
        only, which is where the shared-prefix TTFT win comes from.
        (MoE is excluded from prefix *reuse* upstream: dispatch capacity
        depends on tokens-per-call, so suffix routing can drop different
        tokens than the cold run.)

        int8-KV: ``prefix_k``/``prefix_v`` arrive dequantized (the
        paged pool's ``gather_prefix`` undoes the per-vector scales) and
        the returned cache is requantized whole. Unlike the fp
        families this is *approximate*, not bitwise: suffix queries
        attend over dequantized prefix K/V (≤1/254 relative error vs
        the fp rows the cold prefill used), so layer≥2 suffix K/V and
        the first-token logits carry a quantization-level perturbation
        — greedy tokens agree unless an argmax near-tie flips. Prefix
        rows themselves round-trip exactly (quantize∘dequantize is
        idempotent — the max-|x| element pins each scale) and the
        scheduler never rewrites the shared blocks anyway
        (``write_prefill(skip_blocks=)``), so every *subsequent* decode
        step reads the identical quantized pool either way."""
        cfg, rules = self.cfg, self.rules
        if cfg.family not in PREFIX_FAMILIES:
            raise ValueError(
                f"prefix prefill is only token-identical for {PREFIX_FAMILIES}, "
                f"got {cfg.family!r} (MoE capacity routing / VLM patch rows diverge)"
            )
        h = prefix_k.shape[2]
        B, Ssuf = tokens.shape
        x = embed_tokens(params["embed"], tokens, rules)
        x = constrain(rules, x, ("batch", "seq", None))
        positions = (h + jnp.arange(Ssuf))[None, :].astype(jnp.int32)

        def body(carry, xs):
            x, aux = carry
            lp, pk, pv = xs
            x, a, _kv = self._dense_layer(
                x, lp, "dispatch", positions=positions, prefix_kv=(pk, pv)
            )
            return (x, aux + a), _kv

        (x, _), (k, v) = jax.lax.scan(
            self._maybe_remat(body), (x, 0.0), (params["layers"], prefix_k, prefix_v)
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        if suffix_len is None:
            x_last = x[:, -1]
        else:
            # tokens right-padded to a bucket: last *real* suffix row
            idx = jnp.asarray(suffix_len, jnp.int32) - 1
            x_last = x[jnp.arange(B), idx]
        logits = jnp.einsum("bd,dv->bv", x_last, head)
        cache = self.init_cache(B, max_seq)
        if cfg.kv_quant:
            kq, ks = attn.quantize_kv(k)
            vq, vs = attn.quantize_kv(v)
            for name, val in (("k", kq), ("k_scale", ks), ("v", vq), ("v_scale", vs)):
                cache[name] = jax.lax.dynamic_update_slice_in_dim(
                    cache[name], val, 0, axis=2
                )
        else:
            cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=2)
            cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=2)
        if suffix_len is None:
            cache["len"] = jnp.full_like(cache["len"], h + Ssuf)
        else:
            cache["len"] = (h + jnp.asarray(suffix_len, jnp.int32)).astype(
                cache["len"].dtype
            )
        return logits, cache

    # ------------------------------------------------------------------
    # chunked prefill (serve/scheduler.py token-budget step loop)
    def prefill_chunk(self, params, cache, tokens, n_valid, *, backend=None):
        """One chunk of an incremental prefill: run ``tokens`` [B, W]
        (right-padded to the pow2 bucket W) against a partially filled
        dense cache and commit ``n_valid`` [B] new rows.

        This IS the speculative ``verify_step`` — W queries attend over
        cached earlier-chunk KV plus themselves via the same per-query
        causal mask at a data offset (``pos < len + t + 1``) — except
        the length advance is ``n_valid`` (data) instead of W (shape),
        so pad rows never commit: their K/V land past the new ``len``,
        masked off and overwritten by the next chunk. One jit trace per
        bucket W; chunk position is data (``cache['len']``), so walking
        a prompt never retraces. Returns (logits [B, W, V], new cache);
        ``logits[b, n_valid[b]-1]`` predicts the token after the last
        real chunk token. ``CHUNKED_PREFILL_FAMILIES`` only."""
        if self.cfg.family not in CHUNKED_PREFILL_FAMILIES:
            raise ValueError(
                f"chunked prefill is only token-identical for "
                f"{CHUNKED_PREFILL_FAMILIES}, got {self.cfg.family!r}"
            )
        logits, new_cache = self.verify_step(params, cache, tokens, backend=backend)
        new_cache["len"] = (
            cache["len"] + jnp.asarray(n_valid, jnp.int32)
        ).astype(cache["len"].dtype)
        return logits, new_cache

    def seed_cache_with_prefix(self, prefix_k, prefix_v, max_seq):
        """Dense batch-1 cache pre-loaded with a prefix-cache hit, ready
        for ``prefill_chunk`` to continue at ``len = h``.

        ``prefix_k``/``prefix_v`` [L, 1, h, KV, hd] arrive dequantized
        (``gather_prefix``); int8 configs requantize on write — the
        round-trip is exact (the max-|x| element pins each scale), so
        the seeded rows match the pool bitwise. Host-side glue, not
        jitted: runs once per admission, shapes vary with h."""
        cfg = self.cfg
        if cfg.family not in PREFIX_FAMILIES:
            raise ValueError(
                f"prefix seeding is only token-identical for {PREFIX_FAMILIES}, "
                f"got {cfg.family!r}"
            )
        h = prefix_k.shape[2]
        cache = self.init_cache(prefix_k.shape[1], max_seq)
        if cfg.kv_quant:
            kq, ks = attn.quantize_kv(jnp.asarray(prefix_k))
            vq, vs = attn.quantize_kv(jnp.asarray(prefix_v))
            seeds = (("k", kq), ("k_scale", ks), ("v", vq), ("v_scale", vs))
        else:
            seeds = (("k", prefix_k), ("v", prefix_v))
        # assemble on the host: h varies per admission, and a per-h
        # XLA update-slice would compile inside the serving window
        for name, val in seeds:
            buf = np.zeros(cache[name].shape, cache[name].dtype)
            buf[:, :, :h] = np.asarray(val)
            cache[name] = jnp.asarray(buf)
        cache["len"] = jnp.full_like(cache["len"], h)
        return cache
