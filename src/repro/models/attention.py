"""GQA attention: chunked-causal prefill/train and single-token decode.

The prefill path is a pure-jnp flash-equivalent (running-max softmax over
KV chunks) so activation memory stays O(S·chunk) rather than O(S²) — this
is the reference semantics for ``kernels/flash_attention.py`` and the path
the multi-pod dry-run lowers.

Two causal blocking modes (the §Perf hillclimb axis):
  masked      — every q attends over all KV chunks with a mask (2× causal
                FLOPs, smallest HLO)
  triangular  — python-unrolled q-blocks, each contracting only its causal
                KV prefix (≈½ the FLOPs, bigger HLO)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.models.layers import apply_rope, constrain, normal, rope_tables
from repro.parallel.collectives import distributed_softmax

NEG = -1e30


def init_attention(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d**-0.5
    if cfg.attn_flat_tp:
        # head-agnostic layout: projections shard the FLATTENED q/kv dim
        # over 'model' even when n_heads ∤ mesh (phi3 40H, smollm 9H) —
        # weights and their grads stay sharded; the head structure is
        # recovered by a reshape + resharding constraint at attention
        # entry (EXPERIMENTS.md §Perf hillclimb C it.4).
        params = {
            "wq": normal(ks[0], (d, h * hd), s, dtype),
            "wk": normal(ks[1], (d, kv * hd), s, dtype),
            "wv": normal(ks[2], (d, kv * hd), s, dtype),
            "wo": normal(ks[3], (h * hd, d), (h * hd) ** -0.5, dtype),
        }
        axes = {
            "wq": ("embed", "qdim"),
            "wk": ("embed", "qdim"),
            "wv": ("embed", "qdim"),
            "wo": ("qdim", "embed"),
        }
        return params, axes
    params = {
        "wq": normal(ks[0], (d, h, hd), s, dtype),
        "wk": normal(ks[1], (d, kv, hd), s, dtype),
        "wv": normal(ks[2], (d, kv, hd), s, dtype),
        "wo": normal(ks[3], (h, hd, d), (h * hd) ** -0.5, dtype),
    }
    axes = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    return params, axes


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, kv, n_rep, hd)
    ).reshape(b, s, kv * n_rep, hd)


# ---------------------------------------------------------------------------
# prefill / train


def _attn_chunk_step(q, kc, vc, k_pos, q_pos, m, l, acc, scale):
    """One flash step: q [B,Sq,H,hd] against one KV chunk [B,Ck,H,hd]."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kc).astype(jnp.float32) * scale
    mask = q_pos[None, None, :, None] >= k_pos[None, None, None, :]
    s = jnp.where(mask, s, NEG)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vc.dtype), vc)
    acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv.astype(jnp.float32)
    return m_new, l_new, acc_new


def gqa_attention(
    q,
    k,
    v,
    *,
    q_offset=0,
    chunk: int = 512,
    blocking: str = "masked",
    rules=None,
):
    """Causal GQA attention. q [B,Sq,H,hd]; k,v [B,Skv,KV,hd] (RoPE'd).

    q_offset: absolute position of q[0] (Sq may be a suffix of Skv).
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    n_rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, Skv)
    while Skv % chunk:
        chunk -= 1
    nc = Skv // chunk
    q_pos = q_offset + jnp.arange(Sq)

    m0 = jnp.full((B, H, Sq), NEG, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, hd), jnp.float32)

    if blocking == "triangular" and q_offset == 0 and Sq == Skv:
        out = _triangular_attention(q, k, v, n_rep, scale, chunk, rules)
        return constrain(rules, out, ("batch", "seq_sp", "heads", None))

    def body(carry, idx):
        m, l, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=1)
        kc, vc = _repeat_kv(kc, n_rep), _repeat_kv(vc, n_rep)
        k_pos = idx * chunk + jnp.arange(chunk)
        m, l, acc = _attn_chunk_step(q, kc, vc, k_pos, q_pos, m, l, acc, scale)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    out = out.astype(q.dtype)
    return constrain(rules, out, ("batch", "seq_sp", "heads", None))


def _triangular_attention(q, k, v, n_rep, scale, chunk, rules):
    """Unrolled q-blocks, each over only its causal KV prefix (½ FLOPs)."""
    B, Sq, H, hd = q.shape
    nq = Sq // chunk
    outs = []
    for i in range(nq):
        qi = jax.lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
        kv_len = (i + 1) * chunk
        ki = _repeat_kv(jax.lax.slice_in_dim(k, 0, kv_len, axis=1), n_rep)
        vi = _repeat_kv(jax.lax.slice_in_dim(v, 0, kv_len, axis=1), n_rep)
        q_pos = i * chunk + jnp.arange(chunk)
        k_pos = jnp.arange(kv_len)
        m0 = jnp.full((B, H, chunk), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, chunk), jnp.float32)
        a0 = jnp.zeros((B, chunk, H, hd), jnp.float32)
        m, l, acc = _attn_chunk_step(qi, ki, vi, k_pos, q_pos, m0, l0, a0, scale)
        outs.append(
            (acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]).astype(q.dtype)
        )
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# decode


def scatter_token(stack, new, cache_len, layer_idx):
    """Append tokens per batch row into a layer-stacked cache, each row
    at its *own* length. ``stack`` [L,B,Smax,...]; ``new`` [B,T,...]
    (T=1 for decode, T=K+1 for speculative verify — the slice write
    appends all T rows starting at the row's length); ``cache_len``
    [B]. Under continuous batching the batch rows are slots of
    different requests decoding at divergent positions, so the write
    position is per-row — not the shared ``cache_len[0]`` a fixed batch
    would allow."""
    zero = jnp.int32(0)

    def one(stack_b, new_b, pos):
        start = (layer_idx, pos) + (zero,) * (stack_b.ndim - 2)
        return jax.lax.dynamic_update_slice(stack_b, new_b[None], start)

    return jax.vmap(one, in_axes=(1, 0, 0), out_axes=1)(stack, new, cache_len)


def scatter_token_flat(cache, new, cache_len):
    """Per-row token append for a per-layer (non-stacked) cache:
    ``cache`` [B,Smax,...]; ``new`` [B,T,...]; ``cache_len`` [B]."""
    return jax.vmap(
        lambda cb, nb, pos: jax.lax.dynamic_update_slice_in_dim(cb, nb, pos, axis=0)
    )(cache, new, cache_len)


def gather_block_rows(pool_leaf, block_tables):
    """Dense per-row cache view of a block-paged pool leaf.

    ``pool_leaf`` [L, NB, BS, ...] (NB physical blocks of BS tokens);
    ``block_tables`` [B, MB] int block ids per decode row. Returns
    [L, B, MB·BS, ...] — each row's logical KV sequence gathered through
    its block table. Fixed shape regardless of how many blocks a row
    actually owns (unowned table entries point at the null block and are
    masked off by ``cache_len`` in ``decode_attention``).

    This dense materialization is the *reference/differential* path of
    the paged layout (and the prefix-reuse ``gather_prefix``); the
    serving hot path walks the tables inside the block-paged kernel
    instead (DESIGN.md §4, §3.1)."""
    mb = block_tables.shape[1]
    t = jnp.take(pool_leaf, block_tables, axis=1)  # [L, B, MB, BS, ...]
    return t.reshape(t.shape[:2] + (mb * pool_leaf.shape[2],) + t.shape[4:])


def scatter_block_token(pool_leaf, token_rows, block_ids, offsets):
    """Append one token per decode row into its *tail block* in place.

    ``pool_leaf`` [L, NB, BS, ...]; ``token_rows`` [L, B, ...] (the new
    token's KV rows); ``block_ids``/``offsets`` [B] — per-row physical
    block and in-block position of the write. Rows map to distinct live
    blocks (shared prefix blocks are immutable and never a write
    target), so the scatter is conflict-free; dead rows target the null
    block."""
    return pool_leaf.at[:, block_ids, offsets].set(token_rows)


def scatter_block_tokens(pool_leaf, token_rows, block_ids, offsets):
    """Append T tokens per decode row into their tail blocks in place.

    ``pool_leaf`` [L, NB, BS, ...]; ``token_rows`` [L, B, T, ...] (the
    speculative-verify KV rows); ``block_ids``/``offsets`` [B, T] —
    per-token physical block and in-block position (the T positions may
    span a block boundary; the scheduler pre-claims every tail block
    the verify can reach via ``ensure_tail_n``). Live rows write
    exclusively-owned blocks; dead rows' table entries all point at the
    null block, so their (possibly colliding) writes land in scratch."""
    return pool_leaf.at[:, block_ids, offsets].set(token_rows)


def _dense_as_pool(leaf, bs):
    """A dense per-row cache leaf [B, Smax, ...] viewed as a block pool
    [B·MB, BS, ...] — reshape only, no data movement — so the slot
    layout routes through the same block-paged kernel as the paged one
    (with the identity block table)."""
    B, Smax = leaf.shape[0], leaf.shape[1]
    return leaf.reshape((B * (Smax // bs), bs) + leaf.shape[2:])


def _dense_block_size(smax, bs=256):
    """Largest divisor of ``smax`` that is ≤ ``bs`` — the identity-table
    pool view must tile the dense cache exactly."""
    bs = min(bs, smax)
    while smax % bs:
        bs -= 1
    return bs


def _kernel_cached_attention(q, k_cache, v_cache, cache_len, k_scale, v_scale, backend):
    """Dense-cache decode/verify through the block-paged kernel: the
    [B, Smax] cache is exactly a block pool with an identity table, so
    one kernel serves both KV layouts. ``cache_len`` is the committed
    length — query t attends positions < cache_len + t + 1."""
    B, T, H, hd = q.shape
    Smax = k_cache.shape[1]
    bs = _dense_block_size(Smax)
    if bs < min(8, Smax):
        # a (near-)prime max_seq has no usable tiling: the kernel grid
        # would degrade to up to Smax single-token blocks, all DMA and
        # rescale overhead. Keep the semantics and take the reference
        # numerics for this shape instead — the registry contract is
        # "same tokens", and the advisor gate measures whatever runs.
        return _cached_attention(
            q, k_cache, v_cache, cache_len,
            k_scale=k_scale, v_scale=v_scale, backend="reference",
        )
    tables = jnp.arange(B * (Smax // bs), dtype=jnp.int32).reshape(B, Smax // bs)
    return kernel_ops.paged_attention(
        q,
        _dense_as_pool(k_cache, bs),
        _dense_as_pool(v_cache, bs),
        tables,
        cache_len,
        None if k_scale is None else _dense_as_pool(k_scale, bs),
        None if v_scale is None else _dense_as_pool(v_scale, bs),
        mode=backend,
    )


def paged_attention(
    q, k_pool, v_pool, block_tables, cache_len,
    *, k_scale=None, v_scale=None, rules=None, backend=None,
):
    """Backend-dispatched paged decode/verify attention for one layer.

    q [B,T,H,hd]; pools [NB,BS,KV,hd] (+ per-vector int8 scales);
    ``block_tables`` [B,MB]; ``cache_len`` [B] committed lengths (the
    new token rows are already scattered into the tail blocks; query t
    attends positions < cache_len + t + 1). The kernel backends walk
    the tables directly; the reference backend is the dense
    ``gather_block_rows`` materialization — kept as the differential
    oracle, no longer the serving hot path (DESIGN.md §4)."""
    backend = kernel_ops.resolve_attention_backend(backend)
    if backend != "reference" and rules is None:
        return kernel_ops.paged_attention(
            q, k_pool, v_pool, block_tables, cache_len, k_scale, v_scale,
            mode=backend,
        )
    kd = gather_block_rows(k_pool[None], block_tables)[0]  # [B, MB·BS, KV, hd]
    vd = gather_block_rows(v_pool[None], block_tables)[0]
    if k_scale is not None:
        kd = dequantize_kv(kd, gather_block_rows(k_scale[None], block_tables)[0], q.dtype)
        vd = dequantize_kv(vd, gather_block_rows(v_scale[None], block_tables)[0], q.dtype)
    return _cached_attention(q, kd, vd, cache_len, rules=rules, backend="reference")


def paged_flash_partials(
    q, k_pool, v_pool, block_tables, cache_len, owned,
    *, k_scale=None, v_scale=None, backend=None,
):
    """Per-rank flash running-form partials for kv-sequence-split serving.

    Same inputs as ``paged_attention`` on a LOCAL pool shard, with the
    tables already localized to this rank (unowned entries point at the
    rank's scratch block) and ``owned`` [B, MB] marking which table
    entries this rank's shard actually holds. Returns the unnormalized
    flash triple over owned positions only —

        m   [B, T, H]      running max of the masked logits
        l   [B, T, H]      Σ exp(logit − m)
        acc [B, T, H, hd]  Σ exp(logit − m) · v   (float32)

    — for ``collectives.distributed_softmax`` to combine across the seq
    mesh axis. A rank holding zero valid positions for a row reports the
    NEG sentinel / zero / zeros, which the combine's empty-shard guard
    turns into scale 0 (DESIGN.md §5). Kernel backends run the paged
    kernel's partials mode; the reference path mirrors the
    decode/verify masked softmax with the ownership mask folded in.
    """
    if kernel_ops.resolve_attention_backend(backend) != "reference":
        return kernel_ops.paged_attention_partials(
            q, k_pool, v_pool, block_tables, cache_len, owned,
            k_scale, v_scale, mode=backend,
        )
    kd = gather_block_rows(k_pool[None], block_tables)[0]  # [B, MB·BS, KV, hd]
    vd = gather_block_rows(v_pool[None], block_tables)[0]
    if k_scale is not None:
        kd = dequantize_kv(kd, gather_block_rows(k_scale[None], block_tables)[0], q.dtype)
        vd = dequantize_kv(vd, gather_block_rows(v_scale[None], block_tables)[0], q.dtype)
    B, T, H, hd = q.shape
    S, KV = kd.shape[1], kd.shape[2]
    bs = S // block_tables.shape[1]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, T, KV, g, hd)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, kd).astype(jnp.float32) * scale
    pos_ok = (
        jnp.arange(S)[None, None, :]
        < (cache_len[:, None] + jnp.arange(T)[None, :] + 1)[:, :, None]
    )  # [B, T, S] — query t attends positions < cache_len + t + 1
    own_ok = jnp.repeat(owned, bs, axis=1)  # [B, MB] → per-position [B, S]
    valid = pos_ok & own_ok[:, None, :]
    s = jnp.where(valid[:, None, None, :, :], s, NEG)
    m = s.max(axis=-1)  # [B, KV, g, T]
    p = jnp.where(valid[:, None, None, :, :], jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgts,bskd->btkgd", p, vd.astype(jnp.float32))
    m = m.transpose(0, 3, 1, 2).reshape(B, T, H)
    l = l.transpose(0, 3, 1, 2).reshape(B, T, H)
    return m, l, acc.reshape(B, T, H, hd)


def block_write_positions(block_tables, cache_len, t, block_size):
    """Per-row (physical block id, in-block offset), each [B, t], for
    the ``t`` write positions starting at each row's committed length —
    THE table walk every paged decode/verify write goes through (the t
    positions may span block boundaries; dead rows' unowned table
    entries resolve to the null block, so their writes land in
    scratch)."""
    pos = cache_len[:, None] + jnp.arange(t)[None, :]
    bid = jnp.take_along_axis(block_tables, pos // block_size, axis=1)
    return bid, pos % block_size


def verify_attention(q, k_cache, v_cache, cache_len, *, rules=None, backend=None):
    """Multi-token (speculative verify) attention over the decode cache.

    q [B,T,H,hd] are T proposed tokens at absolute positions
    ``cache_len + arange(T)`` (their KV rows already scattered into the
    caches); caches [B,Smax,KV,hd]; cache_len [B] committed lengths.
    Query t attends to cache positions < cache_len + t + 1 — the same
    single-pass masked softmax as ``decode_attention`` with one extra
    *static* query axis, so each query row's reduction runs over the
    identical masked [Smax] series the sequential decode would see. T
    is shape, acceptance is data: one trace serves every acceptance
    pattern at a given speculation depth (DESIGN.md §3.2). Non-reference
    backends route through the block-paged kernel's K+1-query variant
    (identity block table); sharded callers (``rules`` set) stay on the
    reference path — the kernel is not SPMD-partitioned.
    """
    backend = kernel_ops.resolve_attention_backend(backend)
    if backend != "reference" and rules is None:
        return _kernel_cached_attention(
            q, k_cache, v_cache, cache_len, None, None, backend
        )
    B, T, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, T, KV, g, hd)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k_cache).astype(jnp.float32) * scale
    valid = (
        jnp.arange(Smax)[None, None, :]
        < (cache_len[:, None] + jnp.arange(T)[None, :] + 1)[:, :, None]
    )  # [B, T, Smax]
    s = jnp.where(valid[:, None, None, :, :], s, NEG)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :, :], jnp.exp(s - m), 0.0)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum(
        "bkgts,bskd->btkgd",
        (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
        v_cache,
    )
    return out.reshape(B, T, H, hd)


def _cached_attention(
    q, k_cache, v_cache, cache_len, *, k_scale=None, v_scale=None,
    rules=None, backend=None,
):
    """Dispatch decode-cache attention on the backend and the (static)
    query count: the reference backend keeps the exact jnp decode/verify
    numerics (dequantizing int8 caches first, as before the registry);
    kernel backends view the dense cache as an identity-table block pool
    and run the paged Pallas kernel — T=1 is plain decode, T>1 the
    speculative verify, int8 scales dequantize in-kernel. Sharded
    callers (``rules`` set) always take the reference path: the kernel
    is not SPMD-partitioned, and silently replicating a seq-sharded
    cache would be worse than the jnp flash-decode semantics the
    reference implements (partial max/sum + all-reduce)."""
    backend = kernel_ops.resolve_attention_backend(backend)
    if backend != "reference" and rules is None:
        return _kernel_cached_attention(
            q, k_cache, v_cache, cache_len, k_scale, v_scale, backend
        )
    if k_scale is not None:
        k_cache = dequantize_kv(k_cache, k_scale, q.dtype)
        v_cache = dequantize_kv(v_cache, v_scale, q.dtype)
    if q.shape[1] == 1:
        return decode_attention(
            q, k_cache, v_cache, cache_len + 1, rules=rules, backend="reference"
        )
    return verify_attention(
        q, k_cache, v_cache, cache_len, rules=rules, backend="reference"
    )


def decode_attention(q, k_cache, v_cache, cache_len, *, rules=None, backend=None):
    """One-token attention over a (possibly seq-sharded) KV cache.

    q [B,1,H,hd]; caches [B,Smax,KV,hd]; cache_len [B] valid lengths
    (positions < cache_len participate). Softmax over the sharded Smax dim
    partitions into partial max/sum + all-reduce (flash-decode semantics).
    Non-reference backends route through the block-paged kernel (identity
    block table; the kernel's committed length is ``cache_len - 1`` since
    its single query attends one position past it); sharded callers
    (``rules`` set) stay on the reference path — the kernel is not
    SPMD-partitioned, and these flash-decode semantics are what the
    seq-sharded dry-run lowers.
    """
    backend = kernel_ops.resolve_attention_backend(backend)
    if backend != "reference" and rules is None:
        return _kernel_cached_attention(
            q, k_cache, v_cache, cache_len - 1, None, None, backend
        )
    B, _, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(Smax)[None, :] < cache_len[:, None]  # [B, Smax]
    s = jnp.where(valid[:, None, None, :], s, NEG)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m), 0.0)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (beyond-paper; EXPERIMENTS.md §Perf #zamba2)


def quantize_kv(x):
    """x [..., hd] → (int8 values, per-vector scale). Exactly invertible
    up to 1/254 relative error; halves decode cache bandwidth vs bf16."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6)
    q = jnp.round(x.astype(jnp.float32) / scale * 127.0).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.bfloat16)


def dequantize_kv(q, scale, dtype):
    return (
        q.astype(jnp.float32) * (scale.astype(jnp.float32) / 127.0)[..., None]
    ).astype(dtype)


# ---------------------------------------------------------------------------
# full block


def attention_block(
    params,
    x,
    *,
    cfg,
    rules=None,
    positions=None,
    cache=None,
    cache_len=None,
    prefix_kv=None,
    backend=None,
    tp_axis=None,
    seq_axis=None,
):
    """Pre-norm'd GQA attention. Returns (out, new_cache_kv).

    Train/prefill: cache is None → causal self-attention, cache returned
    when ``cfg`` asks (prefill writes the cache it produced).
    Suffix prefill: ``prefix_kv`` = (k, v) [B,h,KV,hd] of an already-
    computed (prefix-cache hit) prompt prefix; x is the suffix only and
    attends over prefix + suffix with ``q_offset=h``. Per-query flash
    accumulation is independent of which query rows run, so suffix rows
    come out bitwise-identical to a cold full-prompt prefill.
    Decode: x is [B,1,D]; cache = (k,v) [B,Smax,KV,hd]; cache_len [B].
    Chunked prefill rides the decode-cache path with S=W queries: the
    verify-style per-query mask (``pos < cache_len + t + 1``) is exactly
    the causal mask at a running data offset, so each chunk attends over
    earlier-chunk KV + itself — same math as the ``prefix_kv`` branch,
    with the prefix read from the cache instead of concatenated.
    Paged decode/verify: cache is a dict {k, v[, k_scale, v_scale],
    tables, li} of layer-stacked pool leaves [L,NB,BS,KV,hd] plus the
    per-row block tables — the new token rows scatter into each row's
    tail block and attention walks the tables (DESIGN.md §4).
    ``backend`` picks the decode/verify attention backend (None → the
    ops registry default).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if params["wq"].ndim == 2:  # flat-TP layout (attn_flat_tp)
        q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, H, hd)
        k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(B, S, KV, hd)
        v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(B, S, KV, hd)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = constrain(rules, q, ("batch", "seq_sp", "heads", None))
    k = constrain(rules, k, ("batch", None, "kv_heads", None))
    v = constrain(rules, v, ("batch", None, "kv_heads", None))

    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None and prefix_kv is not None:
        pk, pv = prefix_kv
        k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        out = gqa_attention(
            q,
            k_full,
            v_full,
            q_offset=pk.shape[1],
            chunk=cfg.attn_chunk,
            blocking=cfg.causal_blocking,
            rules=rules,
        )
        new_kv = (k_full, v_full)  # full prefix+suffix KV, cache-fillable
    elif cache is None:
        out = gqa_attention(
            q, k, v, chunk=cfg.attn_chunk, blocking=cfg.causal_blocking, rules=rules
        )
        new_kv = (k, v)
    elif isinstance(cache, dict):
        # block-paged pool (layer-stacked leaves + per-row tables): the
        # new token rows scatter into each row's tail block, then the
        # backend attends through the tables — no dense gather on the
        # kernel backends (DESIGN.md §4). Dead rows' tables point at the
        # null block, so their writes land in scratch.
        tables, li = cache["tables"], cache["li"]
        bs = cache["k"].shape[2]
        T = k.shape[1]
        owned = None
        if seq_axis is not None:
            # kv-sequence split (shard_map body): the pool leaves here are
            # this rank's block-dim shard. Localize the replicated tables
            # — owned entries become local slot ids, unowned entries the
            # rank's scratch slot — so writes land on the owner (scratch
            # elsewhere) and attention knows which positions are real.
            from repro.serve.kv_cache import local_table_view

            tables, owned = local_table_view(
                tables, cache["k"].shape[1], jax.lax.axis_index(seq_axis)
            )
        bid, off = block_write_positions(tables, cache_len, T, bs)
        quant = "k_scale" in cache
        if quant:
            k_q, k_s = quantize_kv(k)
            v_q, v_s = quantize_kv(v)
            writes = (("k", k_q), ("k_scale", k_s), ("v", v_q), ("v_scale", v_s))
        else:
            writes = (("k", k), ("v", v))
        stacks = {
            name: cache[name].at[li, bid, off].set(val.astype(cache[name].dtype))
            for name, val in writes
        }
        leaf = lambda name: jax.lax.dynamic_index_in_dim(
            stacks[name], li, 0, keepdims=False
        )
        if owned is None:
            out = paged_attention(
                q,
                leaf("k"),
                leaf("v"),
                tables,
                cache_len,
                k_scale=leaf("k_scale") if quant else None,
                v_scale=leaf("v_scale") if quant else None,
                rules=rules,
                backend=backend,
            )
        else:
            # each rank attends over its owned positions only; the exact
            # combine (with the empty-shard guard) reassembles the global
            # softmax across the seq mesh axis — rounding-level, not
            # bitwise (DESIGN.md §5)
            m_p, l_p, acc_p = paged_flash_partials(
                q,
                leaf("k"),
                leaf("v"),
                tables,
                cache_len,
                owned,
                k_scale=leaf("k_scale") if quant else None,
                v_scale=leaf("v_scale") if quant else None,
                backend=backend,
            )
            out = distributed_softmax(m_p, l_p, acc_p, seq_axis).astype(q.dtype)
        new_kv = tuple(stacks[name] for name, _ in writes)
    elif len(cache) == 5:
        # int8-quantized stacked cache: (k_all int8, k_scale, v_all int8,
        # v_scale, layer_idx). Reads move half the bytes of bf16.
        k_all, ks_all, v_all, vs_all, li = cache
        k_q, k_s = quantize_kv(k)
        v_q, v_s = quantize_kv(v)
        k_all = scatter_token(k_all, k_q, cache_len, li)
        ks_all = scatter_token(ks_all, k_s, cache_len, li)
        v_all = scatter_token(v_all, v_q, cache_len, li)
        vs_all = scatter_token(vs_all, v_s, cache_len, li)
        take = lambda s: jax.lax.dynamic_index_in_dim(s, li, 0, keepdims=False)
        out = _cached_attention(
            q,
            take(k_all),
            take(v_all),
            cache_len,
            k_scale=take(ks_all),
            v_scale=take(vs_all),
            rules=rules,
            backend=backend,
        )
        new_kv = (k_all, ks_all, v_all, vs_all)
    elif len(cache) == 3:
        # stacked-cache decode: (k_all [L,B,S,KV,hd], v_all, layer_idx).
        # The new token is written in place into the full stack (update =
        # one token, not one layer slice) — the scan carries the stack, so
        # donation aliases it and per-step traffic is O(token), not
        # O(layer cache). See EXPERIMENTS.md §Perf #decode-cache.
        k_all, v_all, li = cache
        k_all = constrain(rules, k_all, (None, "batch", "kv_seq", "kv_heads", None))
        v_all = constrain(rules, v_all, (None, "batch", "kv_seq", "kv_heads", None))
        k_all = scatter_token(k_all, k, cache_len, li)
        v_all = scatter_token(v_all, v, cache_len, li)
        k_cache = jax.lax.dynamic_index_in_dim(k_all, li, 0, keepdims=False)
        v_cache = jax.lax.dynamic_index_in_dim(v_all, li, 0, keepdims=False)
        out = _cached_attention(
            q, k_cache, v_cache, cache_len, rules=rules, backend=backend
        )
        new_kv = (k_all, v_all)
    else:
        k_cache, v_cache = cache
        k_cache = constrain(rules, k_cache, ("batch", "kv_seq", "kv_heads", None))
        v_cache = constrain(rules, v_cache, ("batch", "kv_seq", "kv_heads", None))
        # insert the new token(s) at each row's own cache_len
        k_cache = scatter_token_flat(k_cache, k, cache_len)
        v_cache = scatter_token_flat(v_cache, v, cache_len)
        out = _cached_attention(
            q, k_cache, v_cache, cache_len, rules=rules, backend=backend
        )
        new_kv = (k_cache, v_cache)

    if tp_axis is not None:
        # Head-partitioned serving (shard_map body with a local-view cfg):
        # each rank computed a contiguous head block [r·H_loc, (r+1)·H_loc).
        # Softmax is per-head so the shards are already final — gather them
        # back into global head order and run the FULL (replicated) output
        # projection, which keeps the wo contraction order — and thus the
        # residual stream — bitwise identical to the unsharded step
        # (DESIGN.md §5). kv-sequence splits would instead combine partials
        # via collectives.distributed_softmax before this point.
        out = jax.lax.all_gather(out, tp_axis, axis=2, tiled=True)
    # head count derived from the attention output, not cfg: under tp_axis
    # the gather restores the global head axis while cfg carries local heads
    if params["wo"].ndim == 2:  # flat-TP layout
        o2 = out.astype(x.dtype).reshape(B, out.shape[1], out.shape[2] * hd)
        o2 = constrain(rules, o2, ("batch", "seq_sp", "qdim"))
        out = jnp.einsum("bse,ed->bsd", o2, params["wo"])
    else:
        out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return constrain(rules, out, ("batch", "seq_sp", None)), new_kv
