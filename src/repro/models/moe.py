"""Top-k routed expert MLP with expert parallelism over the 'model' axis.

Two execution paths (selected per workload shape, DESIGN.md §6):

dispatch — train/prefill: tokens are sequence-sharded over the full mesh
    (SP), routed locally, exchanged with ``lax.all_to_all`` over 'model'
    (each model shard owns E/16 experts), expert FFN, reverse all-to-all,
    weighted combine. Capacity-based with dropping (static shapes).

dense — decode (token count < mesh size): tokens stay batch-sharded and
    replicated over 'model'; each model shard computes only its local
    experts' masked contribution and a psum over 'model' combines. This is
    the fine-grained/low-occupancy regime — the paper's latency-critical
    case — and the adviser's overlap model prices both paths.

Without a mesh (CPU smoke tests) both paths collapse to a local reference.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers import constrain, normal


def init_moe(key, cfg, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d**-0.5, f**-0.5
    params = {
        "router": normal(ks[0], (d, e), s_in, jnp.float32),
        "we1": normal(ks[1], (e, d, f), s_in, dtype),
        "we3": normal(ks[2], (e, d, f), s_in, dtype),
        "we2": normal(ks[3], (e, f, d), s_out, dtype),
    }
    # expert weights shard E over 'model' and (fsdp) F over 'data' — the
    # decode path consumes exactly this layout with NO weight gather
    # (EXPERIMENTS.md §Perf hillclimb #dbrx-decode)
    axes = {
        "router": ("embed", None),
        "we1": ("experts", None, "expert_mlp"),
        "we3": ("experts", None, "expert_mlp"),
        "we2": ("experts", "expert_mlp", None),
    }
    return params, axes


def _route(x, router_w, top_k):
    """Returns (gates [T,k] fp32, idx [T,k] int32, aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss: E * Σ_e f_e · p_e
    e = router_w.shape[1]
    me = probs.mean(0)
    ce = jnp.zeros((e,)).at[idx.reshape(-1)].add(1.0) / (idx.size)
    aux = e * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_ffn(xe, we1, we3, we2):
    """xe [..., C, D] × per-expert weights [E, D, F] → [..., C, D]."""
    h = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xe, we1))
    h = h * jnp.einsum("...ecd,edf->...ecf", xe, we3)
    return jnp.einsum("...ecf,efd->...ecd", h, we2)


def _dispatch_local(x, gates, idx, n_experts, capacity):
    """Capacity-based dispatch (local view). Returns (buf [E,C,D], lin_idx,
    gate_flat) where lin_idx[t*k+j] addresses buf.reshape(E*C, D) or E*C
    (dropped)."""
    T, D = x.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)  # [T*k]
    onehot = (flat_e[:, None] == jnp.arange(n_experts)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_in_e = jnp.sum(onehot * pos, axis=1)  # [T*k]
    keep = pos_in_e < capacity
    lin = jnp.where(keep, flat_e * capacity + pos_in_e, n_experts * capacity)
    tok = jnp.arange(T * k) // k
    buf = jnp.zeros((n_experts * capacity, D), x.dtype)
    buf = buf.at[lin].add(x[tok], mode="drop")
    return buf.reshape(n_experts, capacity, D), lin, gates.reshape(-1)


def _combine_local(y_buf, lin, gate_flat, T, k):
    """Inverse of dispatch: gather expert outputs back per token."""
    D = y_buf.shape[-1]
    flat = y_buf.reshape(-1, D)
    res = jnp.take(flat, jnp.minimum(lin, flat.shape[0] - 1), axis=0)
    res = jnp.where((lin < flat.shape[0])[:, None], res, 0.0)
    out = (gate_flat[:, None].astype(res.dtype) * res).reshape(T, k, D).sum(1)
    return out


def moe_capacity(tokens_local: int, cfg) -> int:
    c = math.ceil(tokens_local * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, int(math.ceil(c / 4) * 4))


# ---------------------------------------------------------------------------
# paths


def _moe_reference(x2d, params, cfg):
    """Single-device reference (also the test oracle)."""
    T, D = x2d.shape
    gates, idx, aux = _route(x2d, params["router"], cfg.top_k)
    C = moe_capacity(T, cfg)
    buf, lin, gf = _dispatch_local(x2d, gates, idx, cfg.n_experts, C)
    y = _expert_ffn(buf, params["we1"], params["we3"], params["we2"])
    return _combine_local(y, lin, gf, T, cfg.top_k), aux


def _moe_dispatch_sharded(x2d, params, cfg, rules):
    """Expert-parallel all-to-all path under shard_map."""
    mesh = rules.mesh
    ep = mesh.shape["model"]
    assert cfg.n_experts % ep == 0, (cfg.n_experts, ep)
    token_axes = rules.table["tokens_ep"]
    T = x2d.shape[0]
    n_shards = math.prod(mesh.shape[a] for a in token_axes)
    T_l = T // n_shards
    C = moe_capacity(T_l, cfg)

    def body(x_loc, router_w, we1, we3, we2):
        # x_loc [T_l, D]; expert weights are the local E/ep slice
        gates, idx, aux = _route(x_loc, router_w, cfg.top_k)
        buf, lin, gf = _dispatch_local(x_loc, gates, idx, cfg.n_experts, C)
        el = cfg.n_experts // ep
        buf = buf.reshape(ep, el, C, -1)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0)
        y = _expert_ffn(recv, we1, we3, we2)  # [ep, el, C, D]
        back = jax.lax.all_to_all(y, "model", split_axis=0, concat_axis=0)
        out = _combine_local(
            back.reshape(cfg.n_experts, C, -1), lin, gf, x_loc.shape[0], cfg.top_k
        )
        aux = jax.lax.pmean(aux, token_axes)
        return out, aux

    in_specs = (
        P(token_axes, None),
        P(None, None),
        P("model", None, None),
        P("model", None, None),
        P("model", None, None),
    )
    out_specs = (P(token_axes, None), P())
    fn = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return fn(x2d, params["router"], params["we1"], params["we3"], params["we2"])


# baseline switch (set by launch.dryrun --legacy): the pre-optimization
# dense path demanded F-replicated expert weights, so XLA all-gathered
# the FSDP shards every step (EXPERIMENTS.md §Perf #dbrx-decode)
LEGACY_DENSE = False


def _moe_dense_legacy(x2d, params, cfg, rules):
    """Pre-optimization decode path (kept for baseline measurement)."""
    mesh = rules.mesh
    ep = mesh.shape["model"]
    batch_axes = rules.table["batch"]

    def body(x_loc, router_w, we1, we3, we2):
        el = cfg.n_experts // ep
        my = jax.lax.axis_index("model") * el + jnp.arange(el)
        gates, idx, aux = _route(x_loc, router_w, cfg.top_k)
        g_local = ((idx[:, :, None] == my[None, None, :]) * gates[:, :, None]).sum(1)
        h = jax.nn.silu(jnp.einsum("td,edf->etf", x_loc, we1))
        h = h * jnp.einsum("td,edf->etf", x_loc, we3)
        y = jnp.einsum("etf,efd->etd", h, we2)
        out = jnp.einsum("etd,te->td", y, g_local.astype(y.dtype))
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, batch_axes)
        return out, aux

    in_specs = (
        P(batch_axes, None),
        P(None, None),
        P("model", None, None),  # demands F replicated → per-step gather
        P("model", None, None),
        P("model", None, None),
    )
    out_specs = (P(batch_axes, None), P())
    fn = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return fn(x2d, params["router"], params["we1"], params["we3"], params["we2"])


def _moe_dense_sharded(x2d, params, cfg, rules):
    """Decode path: gather the (tiny) token batch, compute each shard's
    (expert-slice × hidden-slice) partial FFN in place, psum the (tiny)
    output. Weights stay sharded [E/model, D, F/data] — NO weight
    all-gather, unlike the FSDP train layout's default lowering: at
    decode, tokens ≪ weights, so we move tokens to weights (the
    Relic principle — co-locate work with the resident data)."""
    mesh = rules.mesh
    ep = mesh.shape["model"]
    batch_axes = rules.table["batch"]
    n_b = math.prod(mesh.shape[a] for a in batch_axes)
    fsdp_axes = tuple(a for a in ("data",) if rules.cfg.param_sharding == "fsdp")

    def body(x_loc, router_w, we1, we3, we2):
        # x_loc [T_l, D] → all tokens [T, D] (a few hundred KB at decode)
        x_all = jax.lax.all_gather(x_loc, batch_axes, axis=0, tiled=True)
        T = x_all.shape[0]
        el = cfg.n_experts // ep
        my = jax.lax.axis_index("model") * el + jnp.arange(el)
        gates, idx, aux = _route(x_all, router_w, cfg.top_k)
        g_local = (
            (idx[:, :, None] == my[None, None, :]) * gates[:, :, None]
        ).sum(1)
        # we1 [el, D, F_l]: hidden stays F-sharded; we2 [el, F_l, D]
        h = jax.nn.silu(jnp.einsum("td,edf->etf", x_all, we1))
        h = h * jnp.einsum("td,edf->etf", x_all, we3)
        y = jnp.einsum("etf,efd->etd", h, we2)  # partial over F shards
        out = jnp.einsum("etd,te->td", y, g_local.astype(y.dtype))
        out = jax.lax.psum(out, ("model",) + tuple(fsdp_axes))
        # back to the token shard this device owns
        i = jnp.int32(0)
        for a in (batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)):
            i = i * mesh.shape[a] + jax.lax.axis_index(a)
        out = jax.lax.dynamic_slice_in_dim(out, i * (T // n_b), T // n_b, axis=0)
        aux = jax.lax.pmean(aux, batch_axes)
        return out, aux

    f_spec = "data" if rules.cfg.param_sharding == "fsdp" else None
    in_specs = (
        P(batch_axes, None),
        P(None, None),
        P("model", None, f_spec),
        P("model", None, f_spec),
        P("model", f_spec, None),
    )
    out_specs = (P(batch_axes, None), P())
    fn = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return fn(x2d, params["router"], params["we1"], params["we3"], params["we2"])


def moe_block(x, params, cfg, rules=None, path="dispatch"):
    """x [B,S,D] → (y [B,S,D], aux_loss)."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    if rules is None or rules.mesh is None:
        y, aux = _moe_reference(x2d, params, cfg)
    elif path == "dense":
        impl = _moe_dense_legacy if LEGACY_DENSE else _moe_dense_sharded
        y, aux = impl(x2d, params, cfg, rules)
    else:
        y, aux = _moe_dispatch_sharded(x2d, params, cfg, rules)
    return y.reshape(B, S, D).astype(x.dtype), aux
