"""Mamba2 (SSD — state-space duality) block, chunked formulation.

Train/prefill: the sequence is split into chunks of ``cfg.ssm_chunk``;
within a chunk the recurrence is evaluated as a (causal) quadratic
contraction, between chunks a state of shape [B, H, hd, N] is carried by a
``lax.scan`` — the exact algorithm of arXiv:2405.21060 §6, and the
reference semantics for ``kernels/ssd_scan.py``.

Decode: O(1) per-token state update.

n_groups = 1 (B/C shared across heads); A is per-head scalar (Mamba2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import constrain, gated_rms_norm, normal, rms_norm


def init_ssm(key, cfg, dtype):
    d, di, ns = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    h, cw = cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 10)
    s = d**-0.5
    params = {
        "wx": normal(ks[0], (d, di), s, dtype),
        "wz": normal(ks[1], (d, di), s, dtype),
        "wB": normal(ks[2], (d, ns), s, dtype),
        "wC": normal(ks[3], (d, ns), s, dtype),
        "wdt": normal(ks[4], (d, h), s, dtype),
        "conv_x": normal(ks[5], (cw, di), cw**-0.5, dtype),
        "conv_B": normal(ks[6], (cw, ns), cw**-0.5, dtype),
        "conv_C": normal(ks[7], (cw, ns), cw**-0.5, dtype),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out": normal(ks[8], (di, d), di**-0.5, dtype),
    }
    axes = {
        "wx": ("embed", "ssm_inner"),
        "wz": ("embed", "ssm_inner"),
        "wB": ("embed", "state"),
        "wC": ("embed", "state"),
        "wdt": ("embed", "ssm_heads"),
        "conv_x": ("conv", "ssm_inner"),
        "conv_B": ("conv", "state"),
        "conv_C": ("conv", "state"),
        "A_log": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out": ("ssm_inner", "embed"),
    }
    return params, axes


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x [B,S,C], w [K,C]. state [B,K-1,C] (decode).

    Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, C]
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1) :, :]
    return y, new_state


def _ssd_chunk_scan(xh, a, b, c, dt, chunk, rules=None):
    """Chunked SSD. xh [B,S,H,hd]; a [B,S,H] decay (=exp(dt·A)); b,c
    [B,S,N]; dt [B,S,H]. Returns (y [B,S,H,hd], final_state [B,H,hd,N]).
    """
    B, S, H, hd = xh.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    r = lambda t: t.reshape((B, nc, Q) + t.shape[2:]).swapaxes(0, 1)
    xh_, a_, b_, c_, dt_ = r(xh), r(a), r(b), r(c), r(dt)

    la = jnp.cumsum(jnp.log(jnp.maximum(a_, 1e-20)), axis=2)  # [nc,B,Q,H]

    def body(state, args):
        xc, ac_la, bc, cc, dtc = args
        # intra-chunk (causal quadratic): att[i,j] = (c_i·b_j)·exp(la_i-la_j)·dt_j
        seg = jnp.exp(
            jnp.clip(ac_la[:, :, None, :] - ac_la[:, None, :, :], -60.0, 0.0)
        )  # [B,Q,Q,H], la_i - la_j for i>=j
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        cb = jnp.einsum("bin,bjn->bij", cc.astype(jnp.float32), bc.astype(jnp.float32))
        att = cb[..., None] * seg * dtc[:, None, :, :]
        att = jnp.where(causal[None, :, :, None], att, 0.0)
        y_intra = jnp.einsum("bijh,bjhd->bihd", att, xh_f := xc.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "bin,bhdn,bih->bihd", cc.astype(jnp.float32), state, jnp.exp(ac_la)
        )
        # state update: S' = S·exp(la_end) + Σ_j b_j ⊗ (x_j·dt_j·exp(la_end-la_j))
        decay_end = jnp.exp(ac_la[:, -1:, :])  # [B,1,H]
        w = dtc * jnp.exp(jnp.clip(ac_la[:, -1:, :] - ac_la, -60.0, 60.0))  # [B,Q,H]
        state = state * decay_end[:, 0][:, :, None, None] + jnp.einsum(
            "bjhd,bjn,bjh->bhdn", xh_f, bc.astype(jnp.float32), w
        )
        return state, (y_intra + y_inter).astype(xh.dtype)

    s0 = jnp.zeros((B, H, hd, N), jnp.float32)
    s_final, ys = jax.lax.scan(body, s0, (xh_, la, b_, c_, dt_))
    return ys.swapaxes(0, 1).reshape(B, S, H, hd), s_final


def ssm_block(params, x, cfg, rules=None, state=None, want_state=False):
    """Mamba2 block. x [B,S,D].

    Train: state=None → chunked scan, returns (y, None).
    Prefill: state=None, want_state=True → (y, final state dict).
    Decode: state = dict(ssm [B,H,hd,N] fp32, conv_x, conv_B, conv_C)
    → one-step update, returns (y, new_state).
    """
    B, S, D = x.shape
    h, hd, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, params["wx"])
    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    b = jnp.einsum("bsd,dn->bsn", x, params["wB"])
    c = jnp.einsum("bsd,dn->bsn", x, params["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"]).astype(jnp.float32)
        + params["dt_bias"]
    )
    xz = constrain(rules, xz, ("batch", "seq", "ssm_inner"))
    z = constrain(rules, z, ("batch", "seq", "ssm_inner"))

    new_state = None
    if state is None:
        xz, cx = _causal_conv(xz, params["conv_x"])
        b, cb = _causal_conv(b, params["conv_B"])
        c, cc = _causal_conv(c, params["conv_C"])
        if want_state:
            new_state = {"conv_x": cx, "conv_B": cb, "conv_C": cc}
    else:
        xz, cx = _causal_conv(xz, params["conv_x"], state["conv_x"])
        b, cb = _causal_conv(b, params["conv_B"], state["conv_B"])
        c, cc = _causal_conv(c, params["conv_C"], state["conv_C"])
        new_state = {"conv_x": cx, "conv_B": cb, "conv_C": cc}
    xz, b, c = jax.nn.silu(xz), jax.nn.silu(b), jax.nn.silu(c)

    A = -jnp.exp(params["A_log"])  # [H]
    a = jnp.exp(dt * A)  # [B,S,H]
    xh = xz.reshape(B, S, h, hd)
    xh = constrain(rules, xh, ("batch", "seq", "ssm_heads", None))

    if state is None:
        y, s_final = _ssd_chunk_scan(xh, a, b, c, dt, cfg.ssm_chunk, rules)
        if want_state:
            new_state["ssm"] = s_final
    else:
        # one-step recurrence: S' = S·a + dt·(b ⊗ x); y = c·S' (+ skip below)
        s_old = state["ssm"]  # [B,H,hd,N] fp32
        xf = xh[:, 0].astype(jnp.float32)  # [B,H,hd]
        s_new = s_old * a[:, 0][:, :, None, None] + jnp.einsum(
            "bhd,bn,bh->bhdn", xf, b[:, 0].astype(jnp.float32), dt[:, 0]
        )
        y = jnp.einsum("bn,bhdn->bhd", c[:, 0].astype(jnp.float32), s_new)
        y = y[:, None].astype(x.dtype).reshape(B, 1, h, hd)
        new_state["ssm"] = s_new

    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, h * hd)
    y = gated_rms_norm(y, z, params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out"])
    return constrain(rules, out, ("batch", "seq", None)), new_state


def init_ssm_state(cfg, batch, dtype=jnp.float32):
    h, hd, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cw = cfg.ssm_conv
    return {
        "ssm": jnp.zeros((batch, h, hd, ns), jnp.float32),
        "conv_x": jnp.zeros((batch, cw - 1, cfg.ssm_d_inner), dtype),
        "conv_B": jnp.zeros((batch, cw - 1, ns), dtype),
        "conv_C": jnp.zeros((batch, cw - 1, ns), dtype),
    }
