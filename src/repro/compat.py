"""Cross-version JAX shims.

``shard_map`` moved twice: ``jax.experimental.shard_map.shard_map``
(≤ 0.4.x), then ``jax.shard_map`` (≥ 0.6) where the replication-check
keyword was renamed ``check_rep`` → ``check_vma``. Callers here use the
modern spelling; this wrapper maps it onto whatever the installed jax
understands.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def axis_size(axis_name) -> int:
    """``lax.axis_size`` (jax ≥ 0.5); on 0.4.x, ``psum`` of a Python
    literal, which jax constant-folds to the static mesh-axis size."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def is_tracer(x) -> bool:
    """True when `x` is a jax tracer (positive detection — raises on a
    jax version where Tracer cannot be located, rather than silently
    treating everything as concrete)."""
    import jax

    tracer_cls = getattr(jax.core, "Tracer", None)
    if tracer_cls is None:
        from jax._src.core import Tracer as tracer_cls
    return isinstance(x, tracer_cls)


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams`` (new name) / ``TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kw)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None, **kw):
    if check_vma is not None:
        if "check_vma" in _PARAMS:
            kw["check_vma"] = check_vma
        elif "check_rep" in _PARAMS:
            kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
