"""AdamW + global-norm clipping + cosine schedule, from scratch.

Optimizer moments are stored fp32 and shard exactly like their parameters
(same logical axes), so FSDP parameter sharding automatically gives
ZeRO-style optimizer-state sharding.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Any  # first moment (fp32, param tree)
    nu: Any  # second moment (fp32, param tree)


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1

    # ------------------------------------------------------------------
    def init(self, params) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)

    def schedule(self, step):
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        prog = jnp.clip(
            (step - self.warmup_steps)
            / max(1, self.total_steps - self.warmup_steps),
            0.0,
            1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        frac = self.min_lr_frac + (1 - self.min_lr_frac) * cos
        return self.lr * warm * frac

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self.schedule(state.step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return (
            new_params,
            OptState(step=step, mu=new_mu, nu=new_nu),
            {"grad_norm": gnorm, "lr": lr},
        )
