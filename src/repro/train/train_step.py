"""Jitted train step: loss → grads → (optional accumulation) → AdamW.

Gradient accumulation is a ``lax.scan`` over microbatches; the optional
cross-pod int8-compressed gradient reduction (parallel/compression.py)
replaces the pod-axis portion of the all-reduce on the slow link.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.parallel.compression import compressed_psum
from repro.train.optimizer import AdamW, OptState


def make_train_step(
    model,
    opt: AdamW,
    *,
    accum_steps: int = 1,
    compress_pod_grads: bool = False,
    zero2_axes=None,
):
    """Returns step(params, opt_state, batch[, err]) → (params, opt_state,
    metrics[, err]). batch leaves have leading [accum, micro...] when
    accum_steps > 1.

    zero2_axes: the params' logical-axes tree. When set (FSDP configs),
    parameters are sharding-constrained to the TP layout ONCE at step
    entry — XLA hoists a single all-gather out of the accumulation loop
    and transposes it to one reduce-scatter of the gradients (ZeRO-2),
    instead of re-gathering every microbatch and remat segment.
    """
    gather_once = None
    if zero2_axes is not None and model.rules is not None:
        tp_rules = model.rules.tp_view()

        def gather_once(params):
            return jax.tree.map(
                lambda p, ax: tp_rules.constrain(p, ax),
                params,
                zero2_axes,
                is_leaf=lambda x: isinstance(x, tuple),
            )

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads_plain(params, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc_loss, acc_grads = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
            return (acc_loss + loss, acc_grads), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        (loss, grads), metrics = jax.lax.scan(micro, (0.0, zeros), batch)
        inv = 1.0 / accum_steps
        return (
            loss * inv,
            jax.tree.map(lambda m: m[-1], metrics),
            jax.tree.map(lambda g: g * inv, grads),
        )

    def compute_grads_zero2(params, batch):
        """ZeRO-2: differentiate through the whole accumulation with the
        parameters gathered ONCE at entry. The gather's autodiff transpose
        is a single gradient reduce-scatter at the end; the micro body is
        checkpointed so activations stay bounded."""
        if accum_steps == 1:
            def total1(p):
                return loss_fn(gather_once(p), batch)

            (loss, metrics), grads = jax.value_and_grad(total1, has_aux=True)(params)
            return loss, metrics, grads

        def total(p):
            pg = gather_once(p)

            @functools.partial(
                jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable
            )
            def micro(mb):
                return loss_fn(pg, mb)

            def body(acc, mb):
                l, m = micro(mb)
                return acc + l, m

            tot, metrics = jax.lax.scan(body, 0.0, batch)
            return tot / accum_steps, jax.tree.map(lambda m: m[-1], metrics)

        (loss, metrics), grads = jax.value_and_grad(total, has_aux=True)(params)
        return loss, metrics, grads

    compute_grads = compute_grads_zero2 if gather_once is not None else compute_grads_plain

    def step(params, opt_state: OptState, batch):
        loss, metrics, grads = compute_grads(params, batch)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    def step_compressed(params, opt_state: OptState, batch, err):
        """Variant for multi-pod meshes: batch is sharded over
        ('pod','data'); the pod-axis share of the gradient reduction is
        int8-compressed with error feedback."""
        mesh = model.rules.mesh

        loss, metrics, grads = compute_grads(params, batch)

        def pod_reduce(g, e):
            def body(gl, el):
                return compressed_psum(gl, "pod", el)

            # grads are already averaged over the full batch by autodiff;
            # XLA's all-reduce includes the pod axis. To show the slow-link
            # compression explicitly we re-reduce the pod axis on the
            # per-pod partial gradients instead.
            return shard_map(
                body,
                mesh=mesh,
                in_specs=(P(), P()),
                out_specs=(P(), P()),
                check_vma=False,
            )(g, e)

        outs = jax.tree.map(pod_reduce, grads, err)
        grads = jax.tree.map(lambda t: t[0], outs, is_leaf=lambda t: isinstance(t, tuple))
        err = jax.tree.map(lambda t: t[1], outs, is_leaf=lambda t: isinstance(t, tuple))
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics, err

    return step_compressed if compress_pod_grads else step
