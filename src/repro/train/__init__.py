from repro.train.optimizer import AdamW, OptState  # noqa: F401
from repro.train.train_step import make_train_step  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
