"""Training loop with the fault-tolerance features a 1000-node run needs.

* checkpoint/restart: periodic async snapshots (params+opt+data cursor);
  ``Trainer.run`` resumes from the latest published step after any crash.
* induced-failure hook: tests (and chaos drills) raise at a chosen step
  and assert bit-exact continuation after restart.
* straggler watchdog: per-step wall time EWMA; a step slower than
  ``straggler_factor``× the EWMA is logged and triggers an immediate
  checkpoint (preemption hedge — on real clusters slow steps precede
  evictions more often than not).
* elastic resume: checkpoints are mesh-agnostic (ckpt/checkpoint.py);
  pass a different mesh/shardings at restore.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.3
    accum_steps: int = 1
    log_every: int = 10


@dataclass
class TrainerState:
    params: Any
    opt_state: Any
    step: int = 0


class Trainer:
    def __init__(
        self,
        model,
        opt: AdamW,
        data,
        cfg: TrainerConfig,
        *,
        fail_at_step: Optional[int] = None,  # induced-failure hook (tests)
        log_fn: Callable[[str], None] = print,
    ):
        self.model = model
        self.opt = opt
        self.data = data
        self.cfg = cfg
        self.fail_at_step = fail_at_step
        self.log = log_fn
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.step_fn = jax.jit(make_train_step(model, opt, accum_steps=cfg.accum_steps))
        self.events: list[str] = []
        self._ewma: Optional[float] = None

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0) -> TrainerState:
        params, _ = self.model.init(jax.random.key(seed))
        return TrainerState(params=params, opt_state=self.opt.init(params), step=0)

    def _maybe_restore(self, state: TrainerState) -> TrainerState:
        # quiesce any in-flight async save first: an in-process restart
        # (induced-failure tests, elastic resume) may arrive while the
        # publish thread is still renaming the newest step dir
        self.ckpt.wait()
        latest = self.ckpt.latest_step()
        if latest is None:
            return state
        tree = {"params": state.params, "opt": state.opt_state}
        restored, meta = self.ckpt.restore(tree, latest)
        self.events.append(f"restored step {latest}")
        self.log(f"[trainer] restored checkpoint at step {latest}")
        return TrainerState(
            params=restored["params"], opt_state=restored["opt"], step=meta["step"]
        )

    def _save(self, state: TrainerState, blocking=False):
        self.ckpt.save(
            state.step,
            {"params": state.params, "opt": state.opt_state},
            meta={"step": state.step},
            blocking=blocking,
        )

    # ------------------------------------------------------------------
    def run(self, state: TrainerState | None = None, resume: bool = True):
        state = state or self.init_state()
        if resume:
            state = self._maybe_restore(state)
        metrics = {}
        while state.step < self.cfg.total_steps:
            step = state.step
            if self.fail_at_step is not None and step == self.fail_at_step:
                self.fail_at_step = None  # fail once
                self.events.append(f"induced failure at step {step}")
                raise RuntimeError(f"induced node failure at step {step}")

            batch = self.data.batch_at(step)
            if self.cfg.accum_steps > 1:
                a = self.cfg.accum_steps
                batch = jax.tree.map(
                    lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), batch
                )
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(
                state.params, state.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            # straggler watchdog
            if self._ewma is None:
                self._ewma = dt
            elif dt > self.cfg.straggler_factor * self._ewma and step > 2:
                msg = f"straggler step {step}: {dt*1e3:.1f}ms vs EWMA {self._ewma*1e3:.1f}ms — checkpointing"
                self.events.append(msg)
                self.log("[watchdog] " + msg)
                self._save(TrainerState(params, opt_state, step + 1))
            else:
                self._ewma = (
                    self.cfg.ewma_alpha * dt + (1 - self.cfg.ewma_alpha) * self._ewma
                )

            state = TrainerState(params=params, opt_state=opt_state, step=step + 1)
            if state.step % self.cfg.ckpt_every == 0:
                self._save(state)
            if step % self.cfg.log_every == 0:
                self.log(
                    f"[train] step {step} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms"
                )
        self._save(state, blocking=True)
        return state, metrics
