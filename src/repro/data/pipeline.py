"""Deterministic synthetic LM data pipeline, shard-aware.

Batches are a pure function of (seed, step) — restart/elastic-resume
reproduce the exact token stream with zero coordination state, which is
the property a 1000-node input pipeline actually needs (any host can
regenerate any step). The generator is a Zipf-ish unigram mix with local
n-gram structure so losses move during the example runs instead of
flat-lining on uniform noise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class SyntheticLMData:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, step: int, sharding=None):
        """Batch for `step`: {tokens, labels, mask [+ patch_embeds]}."""
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        V = self.cfg.vocab_size
        n_text = self.seq
        if self.cfg.family == "vlm":
            n_text = self.seq - self.cfg.n_frontend_tokens
        # Zipf-flavored unigram draw + shifted-copy bigram structure
        u = jax.random.uniform(k1, (self.batch, n_text + 1), minval=1e-6)
        zipf = (jnp.exp(u * jnp.log(float(V))) - 1.0).astype(jnp.int32) % V
        copy_mask = jax.random.bernoulli(k2, 0.3, (self.batch, n_text + 1))
        rolled = jnp.roll(zipf, 1, axis=1)
        stream = jnp.where(copy_mask, rolled, zipf)
        tokens, labels = stream[:, :-1], stream[:, 1:]
        out = {
            "tokens": tokens,
            "labels": labels,
            "mask": jnp.ones_like(labels, jnp.float32),
        }
        if self.cfg.family == "vlm":
            out["patch_embeds"] = (
                jax.random.normal(
                    k3, (self.batch, self.cfg.n_frontend_tokens, self.cfg.d_model)
                ).astype(jnp.dtype(self.cfg.dtype))
            )
        if sharding is not None:
            out = {
                k: jax.device_put(v, sharding[k]) if k in sharding else v
                for k, v in out.items()
            }
        return out

    def batch_specs(self):
        """ShapeDtypeStructs for lowering (dry-run input_specs)."""
        n_text = self.seq
        if self.cfg.family == "vlm":
            n_text = self.seq - self.cfg.n_frontend_tokens
        sds = {
            "tokens": jax.ShapeDtypeStruct((self.batch, n_text), jnp.int32),
            "labels": jax.ShapeDtypeStruct((self.batch, n_text), jnp.int32),
            "mask": jax.ShapeDtypeStruct((self.batch, n_text), jnp.float32),
        }
        if self.cfg.family == "vlm":
            sds["patch_embeds"] = jax.ShapeDtypeStruct(
                (self.batch, self.cfg.n_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        return sds

    def batch_axes(self):
        ax = {
            "tokens": ("batch", "seq"),
            "labels": ("batch", "seq"),
            "mask": ("batch", "seq"),
        }
        if self.cfg.family == "vlm":
            ax["patch_embeds"] = ("batch", "seq", None)
        return ax
