"""Dry-run cell construction: (arch × shape × mesh) → lowerable step.

``build_cell`` returns everything ``dryrun.py`` needs:
  fn            — the step to lower (train / prefill / serve)
  args          — ShapeDtypeStruct stand-ins for every input (no
                  allocation; the input_specs contract from the brief)
  in_shardings  — NamedShardings for each arg
  donate        — argnums whose buffers alias outputs (memory honesty)
  model_flops   — 6·N·D (train) / 2·N·tokens (inference) for the
                  usefulness ratio in §Roofline
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, cell_is_runnable, get_config, get_shape
from repro.data.pipeline import SyntheticLMData
from repro.models.model import Model
from repro.train.optimizer import AdamW, OptState
from repro.train.train_step import make_train_step


@dataclass
class Cell:
    arch: str
    shape: str
    fn: Any
    args: tuple
    in_shardings: tuple
    donate: tuple
    model_flops: float
    rules_fallbacks: list
    runnable: bool = True
    skip_reason: str = ""


def input_specs(arch: str, shape_name: str, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.kind in ("train", "prefill"):
        data = SyntheticLMData(cfg, batch=shape.global_batch, seq=shape.seq_len)
        return data.batch_specs()
    # decode: one token + KV cache of seq_len
    model = Model(cfg, mesh=mesh)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len)
    )
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "cache": cache,
    }


def _abstract_opt(opt: AdamW, params_shapes):
    return jax.eval_shape(opt.init, params_shapes)


def build_cell(arch: str, shape_name: str, mesh, overrides: dict | None = None) -> Cell:
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return Cell(arch, shape_name, None, (), (), (), 0.0, [], False, why)

    model = Model(cfg, mesh=mesh)
    rules = model.rules
    pshapes, paxes = model.abstract_params()
    pshard = rules.tree_shardings(pshapes, paxes)
    n_active = cfg.active_param_count()

    if shape.kind == "train":
        opt = AdamW(total_steps=10_000)
        oshapes = _abstract_opt(opt, pshapes)
        oshard = OptState(
            step=NamedSharding(mesh, P()),
            mu=rules.tree_shardings(oshapes.mu, paxes),
            nu=rules.tree_shardings(oshapes.nu, paxes),
        )
        # gradient accumulation bounds live activation tokens per device
        # (per-arch train_accum; see EXPERIMENTS.md §Dry-run memory notes)
        accum = max(1, cfg.train_accum)
        micro = shape.global_batch // accum
        data = SyntheticLMData(cfg, batch=micro, seq=shape.seq_len)
        bspecs = data.batch_specs()
        if accum > 1:
            bspecs = {
                k: jax.ShapeDtypeStruct((accum,) + v.shape, v.dtype)
                for k, v in bspecs.items()
            }
            bshard = {
                k: rules.sharding((None,) + ax, bspecs[k].shape)
                for k, ax in data.batch_axes().items()
            }
        else:
            bshard = {
                k: rules.sharding(ax, bspecs[k].shape)
                for k, ax in data.batch_axes().items()
            }
        use_zero2 = bool(cfg.zero2) and cfg.param_sharding == "fsdp"
        step = make_train_step(
            model, opt, accum_steps=accum, zero2_axes=paxes if use_zero2 else None
        )
        fn = step
        args = (pshapes, oshapes, bspecs)
        in_shardings = (pshard, oshard, bshard)
        donate = (0, 1)
        model_flops = 6.0 * n_active * shape.tokens
    elif shape.kind == "prefill":
        data = SyntheticLMData(cfg, batch=shape.global_batch, seq=shape.seq_len)
        bspecs = data.batch_specs()
        tshard = rules.sharding(("batch", "seq"), bspecs["tokens"].shape)

        if cfg.family == "vlm":
            pe = bspecs["patch_embeds"]
            peshard = rules.sharding(("batch", None, None), pe.shape)

            def fn(params, tokens, patch_embeds):
                return model.prefill(params, tokens, shape.seq_len, patch_embeds=patch_embeds)

            args = (pshapes, bspecs["tokens"], pe)
            in_shardings = (pshard, tshard, peshard)
        else:

            def fn(params, tokens):
                return model.prefill(params, tokens, shape.seq_len)

            args = (pshapes, bspecs["tokens"])
            in_shardings = (pshard, tshard)
        donate = ()
        model_flops = 2.0 * n_active * shape.tokens
    else:  # decode
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        cshard = rules.tree_shardings(cache, model.cache_axes(cache))
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tshard = rules.sharding(("batch", None), tok.shape)
        fn = model.decode_step
        args = (pshapes, cache, tok)
        in_shardings = (pshard, cshard, tshard)
        donate = (1,)
        model_flops = 2.0 * n_active * shape.global_batch

    return Cell(
        arch,
        shape_name,
        fn,
        args,
        in_shardings,
        donate,
        model_flops,
        rules.fallbacks,
    )
