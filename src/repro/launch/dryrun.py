import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory / cost / collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun

The 512 placeholder CPU devices exist ONLY here (the first two lines
above, before any jax import, per the brief). Smoke tests and benchmarks
see the real single device.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ALL_ARCHS, SHAPES  # noqa: E402
from repro.core.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.core.overlap_model import HwModel  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

HW = HwModel()


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool,
    verbose: bool = True,
    legacy: bool = False,
    overrides: dict | None = None,
) -> dict:
    """Lower + compile one cell; returns the §Dry-run record."""
    if legacy:  # paper-faithful baseline implementation (see §Perf)
        import repro.models.model as model_mod
        import repro.models.moe as moe_mod

        model_mod.LEGACY_CACHE_SCAN = True
        moe_mod.LEGACY_DENSE = True
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "legacy": legacy,
        "overrides": overrides or {},
    }
    cell = build_cell(arch, shape, mesh, overrides=overrides)
    if not cell.runnable:
        rec["status"] = "skipped"
        rec["reason"] = cell.skip_reason
        if verbose:
            print(f"[dryrun] {arch} × {shape} × {rec['mesh']}: SKIP ({cell.skip_reason})")
        return rec

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    cost = hlo_analyze(text)  # trip-count-aware (core/hlo_cost.py)

    flops = cost.flops
    nbytes = cost.bytes
    terms = {
        "compute_s": flops / HW.peak_flops,
        "memory_s": nbytes / HW.hbm_bw,
        "collective_s": cost.collective_bytes / HW.ici_bw,
    }
    dominant = max(terms, key=terms.get)
    hlo_flops_global = flops * n_chips
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops_per_device=flops,
        bytes_per_device=nbytes,
        xla_flops_per_device=float(xla_cost.get("flops", 0.0)),
        collective_bytes_per_device=cost.collective_bytes,
        collectives={k: int(v) for k, v in cost.collective_counts.items()},
        collective_bytes_by_op={k: float(v) for k, v in cost.collective_by_op.items()},
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_estimate_bytes=mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        ),
        roofline=dict(terms, dominant=dominant.replace("_s", "")),
        model_flops=cell.model_flops,
        useful_flops_ratio=(cell.model_flops / hlo_flops_global) if hlo_flops_global else 0.0,
        sharding_fallbacks=sorted(set(cell.rules_fallbacks)),
    )
    if verbose:
        mem_gib = rec["memory"]["peak_estimate_bytes"] / 2**30
        print(
            f"[dryrun] {arch} × {shape} × {rec['mesh']}: OK "
            f"compile={t_compile:.0f}s mem≈{mem_gib:.2f}GiB/dev "
            f"dominant={rec['roofline']['dominant']} "
            f"useful={rec['useful_flops_ratio']*100:.0f}% "
            f"colls={rec['collectives']}"
        )
        print(f"  memory_analysis: {mem}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--legacy", action="store_true",
                    help="paper-faithful baseline implementation")
    ap.add_argument("--set", action="append", default=[],
                    help="config override, e.g. --set param_sharding=tp")
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = int(v) if v.isdigit() else v

    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}_{shape}_{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached")
                    continue
                try:
                    rec = run_cell(
                        arch, shape, multi_pod=multi,
                        legacy=args.legacy, overrides=overrides or None,
                    )
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2, default=float)
    print(f"\n[dryrun] done; {len(failures)} failures: {failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
