"""Production mesh builders (pure functions — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
