"""Latency-critical serving example: batched greedy decoding with
per-step latency percentiles — optionally with the int8 KV cache, and
optionally advised by Aira (``--aira`` exposes the decode step as a
Region, advises it, and routes decoding through the accepted
RegionPlan).

  PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-2.7b]
      [--int8-kv] [--tokens 32] [--aira]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.serve import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--aira", action="store_true",
                    help="advise the decode step and serve through its RegionPlan")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    model = Model(cfg)
    params, _ = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_seq=256)

    prompts = jax.random.randint(jax.random.key(1), (args.batch, 16), 0, cfg.vocab_size)

    if args.aira:
        from repro.core import Aira, Workload

        region = engine.decode_region(prompts, force=True)
        report = Aira().advise(Workload("serve-decode", lambda: None, [region]))
        print(report.render())
        d = report.decisions[0]
        if d.accepted:
            engine.set_decode_plan(d.plan)
            print("decode routed through RegionPlan:", d.plan.describe())

    out = engine.generate(prompts, args.tokens)
    print(f"arch={args.arch} int8_kv={args.int8_kv} aira={args.aira}")
    print(f"generated {out.shape} tokens; first row: {out[0][:12].tolist()}")
    print(f"decode latency: {engine.stats.summary()}")


if __name__ == "__main__":
    main()
