"""Latency-critical serving example: batched greedy decoding with
per-step latency percentiles — optionally with the int8 KV cache.

  PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-2.7b]
      [--int8-kv] [--tokens 32]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.serve import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    model = Model(cfg)
    params, _ = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_seq=256)

    prompts = jax.random.randint(jax.random.key(1), (args.batch, 16), 0, cfg.vocab_size)
    out = engine.generate(prompts, args.tokens)
    print(f"arch={args.arch} int8_kv={args.int8_kv}")
    print(f"generated {out.shape} tokens; first row: {out[0][:12].tolist()}")
    print(f"decode latency: {engine.stats.summary()}")


if __name__ == "__main__":
    main()
