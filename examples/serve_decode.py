"""Latency-critical serving example on the continuous-batching core.

Two modes:

* fixed batch (default): ``generate()`` decodes a full batch through the
  slot-pool scheduler and prints per-step latency percentiles;
* open loop (``--open-loop N``): N requests with Poisson arrivals
  (``--rate`` req/s), random prompt lengths, and random token budgets
  are admitted into a ``--batch``-slot pool as slots free up — the
  continuous-batching path — and per-request TTFT percentiles are
  reported.

Either mode optionally runs with the int8 KV cache, with the block-
paged KV cache + prefix reuse (``--paged``, attention families), with
speculative decoding (``--spec K``: the n-gram prompt-lookup drafter
proposes K tokens per verify step; greedy token streams are unchanged
by construction, and the run reports the measured acceptance rate —
DESIGN.md §3.2), and optionally advised by Aira (``--aira`` exposes the
decode step as a Region, advises it, and routes decoding through the
accepted RegionPlan — masked over the active slots in open-loop mode;
slotted layout only, and mutually exclusive with ``--spec``).

``--backend`` picks the decode/verify attention backend (DESIGN.md §4):
``reference`` is the pure-jnp path (paged decode gathers a dense view),
``kernel`` the block-paged Pallas kernel compiled for TPU (attention
walks the block tables — no dense gather), ``interpret`` the same
kernel code interpreted on CPU (token-identical by the CI differential
contract), and ``auto`` (default) resolves per platform via the ops
registry (``REPRO_ATTENTION_BACKEND`` overrides).

``--online`` attaches the closed-loop ``OnlineAdviser`` in open-loop
mode (DESIGN.md §9): ``engine.prime()`` pre-jits and price-measures the
K × backend grid, the controller re-decides the speculation depth (and
admission budget under pool pressure) every few steps from the
telemetry windows, and the decision audit trail is printed when the run
finishes. Switching is retrace-free — every arm is a trace-cache hit
after priming — and token streams stay exactly greedy. Mutually
exclusive with ``--aira`` (both rewrite how the decode step is driven);
with ``--spec K`` the controller's candidate depths cap at K.

``--chunk N`` turns on chunked prefill in open-loop mode: at most N
prompt tokens of prefill are admitted per decode step, so a long
prompt's prefill interleaves with running decodes instead of stalling
them (DESIGN.md §3.3; token streams are unchanged by construction).

``--trace PATH`` arms the serving flight recorder (DESIGN.md §8)
before the engine is built and exports Chrome/Perfetto trace-event
JSON to PATH when the run finishes — request lifecycle spans, per-step
phase timings, and any adviser/backend events, loadable in
ui.perfetto.dev or chrome://tracing. Recording is observation only:
token streams are unchanged (the observability benchmark pins this).

``--mesh N`` serves through the tensor-parallel sharded path
(DESIGN.md §5): the paged pool's KV leaves are head-partitioned over an
N-way ``("model",)`` mesh and decode/verify run per-shard under
``shard_map`` — token streams are bitwise those of the single-device
paged path. Requires ``--paged`` and ``--open-loop`` (the mesh is wired
through ``engine.serve(mesh=)``); on a single-device CPU host the
script forces an N-device host platform for you. Architectures whose
kv-head count the mesh does not divide fall back to replicated serving
with a logged warning.

  PYTHONPATH=src python examples/serve_decode.py [--arch zamba2-2.7b]
      [--int8-kv] [--paged] [--spec 4] [--tokens 32] [--batch 4]
      [--aira] [--open-loop 8] [--rate 20] [--backend interpret]
      [--chunk 16] [--mesh 2] [--online] [--trace serve_trace.json]
"""
import argparse
import dataclasses
import os
import sys

# --mesh on a single-device CPU host needs the forced device count set
# BEFORE jax initializes, so peek at argv ahead of the jax import
if "--mesh" in sys.argv[:-1]:
    _n = int(sys.argv[sys.argv.index("--mesh") + 1])
    _flags = os.environ.get("XLA_FLAGS", "")
    if _n > 1 and "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + f" --xla_force_host_platform_device_count={_n}"
        ).strip()

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serve import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4,
                    help="fixed batch size / open-loop slot-pool size")
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV cache with shared-prefix reuse")
    ap.add_argument("--spec", type=int, default=0, metavar="K",
                    help="speculative decoding: K n-gram draft tokens per verify "
                         "(0 = off; token streams stay exactly greedy)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "reference", "kernel", "interpret"),
                    help="decode/verify attention backend (DESIGN.md §4): "
                         "the block-paged Pallas kernel ('kernel'/'interpret') "
                         "or the pure-jnp reference path")
    ap.add_argument("--aira", action="store_true",
                    help="advise the decode step and serve through its RegionPlan")
    ap.add_argument("--open-loop", type=int, default=0, metavar="N",
                    help="serve N Poisson-arrival requests instead of one fixed batch")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="open-loop arrival rate (requests/second)")
    ap.add_argument("--chunk", type=int, default=0, metavar="N",
                    help="chunked prefill: admit at most N prompt tokens of "
                         "prefill per decode step (pow2; 0 = monolithic). "
                         "Long prompts stop stalling co-resident decodes "
                         "(DESIGN.md §3.3)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="tensor-parallel serving: head-partition the paged "
                         "KV pool over an N-way ('model',) mesh and run "
                         "decode/verify per-shard (DESIGN.md §5; requires "
                         "--paged and --open-loop; token streams stay "
                         "bitwise single-device)")
    ap.add_argument("--online", action="store_true",
                    help="closed-loop serving: prime the K × backend grid, "
                         "attach the OnlineAdviser (live K/admission "
                         "re-decision from telemetry windows, retrace-free), "
                         "and print the decision audit trail (DESIGN.md §9; "
                         "requires --open-loop)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="arm the serving flight recorder and export "
                         "Chrome/Perfetto trace-event JSON to PATH "
                         "(DESIGN.md §8; load in ui.perfetto.dev)")
    args = ap.parse_args()

    if args.trace:
        from repro.serve.telemetry import configure

        # arm the module-global recorder before the engine is built so
        # the scheduler's cached metric handles are live for the run
        configure(enabled=True)

    cfg = get_config(args.arch).reduced()
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if args.spec and args.aira:
        raise SystemExit("--spec and --aira both rewrite the decode step; pick one")
    if args.online and args.aira:
        raise SystemExit("--online and --aira both re-decide the decode step; pick one")
    if args.online and not args.open_loop:
        raise SystemExit("--online rides the serve() path; add --open-loop N")
    mesh = None
    if args.mesh > 1:
        if not args.paged:
            raise SystemExit("--mesh shards the paged pool; add --paged")
        if not args.open_loop:
            raise SystemExit("--mesh rides the serve() path; add --open-loop N")
        if len(jax.devices()) < args.mesh:
            raise SystemExit(
                f"--mesh {args.mesh} needs {args.mesh} devices, have "
                f"{len(jax.devices())} (on CPU the script sets "
                f"xla_force_host_platform_device_count for you — is "
                f"XLA_FLAGS already pinning a smaller count?)"
            )
        try:
            mesh = jax.make_mesh(
                (args.mesh,), ("model",),
                axis_types=(jax.sharding.AxisType.Auto,),
            )
        except AttributeError:  # jax 0.4.x: no AxisType
            mesh = jax.make_mesh((args.mesh,), ("model",))
    model = Model(cfg)
    params, _ = model.init(jax.random.key(0))
    from repro.serve import SpecConfig

    engine = ServingEngine(
        model, params, max_seq=256,
        kv_layout="paged" if args.paged else "slot",
        spec=SpecConfig(k=args.spec, drafter="ngram") if args.spec else None,
        attention_backend=args.backend,
    )

    prompts = jax.random.randint(jax.random.key(1), (args.batch, 16), 0, cfg.vocab_size)

    if args.aira:
        from repro.core import Aira, Workload

        region = engine.decode_region(prompts, force=True)
        report = Aira().advise(Workload("serve-decode", lambda: None, [region]))
        print(report.render())
        d = report.decisions[0]
        if d.accepted:
            engine.set_decode_plan(d.plan)
            print("decode routed through RegionPlan:", d.plan.describe())

    print(
        f"arch={args.arch} int8_kv={args.int8_kv} paged={args.paged} "
        f"spec_k={args.spec} aira={args.aira} backend={engine.attention_backend}"
        + (f" mesh={args.mesh}" if mesh is not None else "")
    )
    if args.open_loop > 0:
        from repro.serve.load import make_requests

        reqs = make_requests(
            args.open_loop,
            args.rate,
            vocab=cfg.vocab_size,
            max_new_tokens=args.tokens,
            rng=np.random.default_rng(0),
        )
        controller = None
        if args.online:
            from repro.serve import OnlineAdviser

            # pre-jit + price-measure the candidate grid: every live
            # switch the controller makes is a trace-cache hit
            ks = (0, args.spec) if args.spec else (0, 2, 4)
            primed = engine.prime(args.batch, ks=ks)
            controller = OnlineAdviser(
                ks=primed["ks"], decision_interval=4, window=8, dwell=1,
            )
            controller.seed_costs(primed)
            cells = primed["cells"][engine.attention_backend]
            print(
                "primed: "
                + " ".join(f"K={k}:{ms:.2f}ms" for k, ms in sorted(cells.items()))
            )
        outputs = engine.serve(
            reqs, max_batch=args.batch, chunk_size=args.chunk, mesh=mesh,
            controller=controller,
        )
        for r in reqs:
            print(
                f"  req {r.rid}: arrive={r.arrival_time*1e3:7.1f}ms "
                f"prompt={len(np.asarray(r.prompt)):2d} tokens={len(r.tokens):2d} "
                f"ttft={r.ttft_ms:7.1f}ms e2e={r.e2e_ms:7.1f}ms"
            )
        assert all(len(outputs[r.rid]) == len(r.tokens) for r in reqs)
        print(f"open-loop serving: {engine.stats.summary()}")
        if controller is not None:
            info = engine.stats.serving_summary().get("controller", {})
            print(
                f"online adviser: {info.get('decisions', 0)} decisions, "
                f"{info.get('switches', 0)} switches, final K={info.get('k')} "
                f"backend={info.get('backend')}"
            )
            for d in controller.audit_trail():
                print(
                    f"  step {d['step']:>3}: k={d['k']}"
                    + (" [probe]" if d["probe"] else "")
                    + f" — {d['reason']}"
                )
    else:
        out = engine.generate(prompts, args.tokens)
        print(f"generated {out.shape} tokens; first row: {out[0][:12].tolist()}")
        print(f"decode latency: {engine.stats.summary()}")
    if args.spec:
        # absent when no verify round ever ran (e.g. every request
        # retired on its prefill token)
        s = engine.stats.serving_summary().get("speculative")
        if s is not None:
            print(
                f"speculative: K={s['k']} acceptance={s['acceptance_rate']:.2f} "
                f"({s['accepted']}/{s['proposed']} draft tokens; "
                f"draft p50={s['p50_draft_ms']:.2f}ms verify p50={s['p50_verify_ms']:.2f}ms)"
            )
    if args.trace:
        from repro.serve.telemetry import get_telemetry, validate_chrome_trace

        tracer = get_telemetry().tracer
        counts = validate_chrome_trace(tracer.export(args.trace))
        print(
            f"trace: {counts['events']} events ({counts['spans']} spans, "
            f"{counts['async_spans']} request spans) → {args.trace}"
        )


if __name__ == "__main__":
    main()
