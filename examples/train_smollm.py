"""End-to-end driver: train a ~135M-param smollm on synthetic data for a
few hundred steps with checkpoint/restart fault tolerance.

  PYTHONPATH=src python examples/train_smollm.py [--steps 300] [--full]

Default uses a width-reduced config so a CPU finishes in minutes; --full
uses the real 135M config (slow on CPU — intended for TPU hosts).
"""
import argparse

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import Model
from repro.train import AdamW, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="real 135M config")
    ap.add_argument("--ckpt", default="/tmp/repro_smollm_ckpt")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = cfg.reduced()
    model = Model(cfg)
    data = SyntheticLMData(cfg, batch=args.batch, seq=args.seq)
    opt = AdamW(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    tc = TrainerConfig(
        total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt, log_every=20
    )
    trainer = Trainer(model, opt, data, tc)
    state, metrics = trainer.run()  # resumes automatically if interrupted
    print(
        f"done: step {state.step}, loss {float(metrics['loss']):.4f} "
        f"(checkpoints in {args.ckpt})"
    )


if __name__ == "__main__":
    main()
