"""Quickstart: the Aira pipeline end-to-end on one latency-critical
benchmark — profile → annotate → dependence check → SMT-overlap gate →
Relic restructuring — then the granularity bands of Figs. 1–2.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.bench_suite import BENCHMARKS
from repro.core import Aira
from repro.core.overlap_model import CPU_HW, OPENMP, RELIC, OverlapModel
from repro.bench_suite import cc, pfl

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.fig34_aira import make_workload  # noqa: E402


def main():
    # 1) advise one benchmark ("Parallelize this program with Aira")
    b = BENCHMARKS["GeoSpatial"]
    data = b.build()
    report = Aira(hw=CPU_HW).advise(make_workload(b, data))
    print(report.render())
    d = report.decisions[0]
    if d.accepted:
        # the benchmark declares combine="sum"; the plan honors it
        got = np.asarray(d.parallel_fn())
        want = np.asarray(b.serial_value(data, combine=b.combine))
        print(f"\nrestructured == serial: {np.allclose(got, want, atol=1e-3)}")
        print(f"chosen schedule: {d.schedule.describe()}")

    # 2) the granularity band (paper Figs. 1–2)
    model = OverlapModel(CPU_HW)
    print("\nCC kernel, speedup vs problem size (Relic on one SMT core):")
    for n in (10, 50, 200, 1000):
        g = max(4, n // 4)
        from repro.core.overlap_model import Microtask
        t0 = cc.microtask()
        t = Microtask(t0.flops * g, t0.bytes * g, t0.chain * g, True)
        p = model.predict(t, max(2, n // g))
        print(f"  n={n:5d}: smt2 {p.gain('smt2')*100:+6.1f}%   smp2 {p.gain('smp2')*100:+6.1f}%")


if __name__ == "__main__":
    main()
