"""Apply Aira to YOUR OWN kernel — the paper's "Parallelize this program
with Aira" flow on a user-supplied region.

The advisory run now flows through the tool pipeline (profiler → deps →
simulator → restructurer), and an accepted region comes back with a
cached ``RegionPlan``: re-advising or re-executing the same region
signature reuses the compiled plan instead of retracing.

  PYTHONPATH=src python examples/parallelize_with_aira.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Aira, Region, Workload
from repro.core.overlap_model import CPU_HW
from repro.core.plan import plan_cache_stats


def main():
    # a latency-critical kernel: per-query nearest centroid (gather-heavy)
    centroids = jax.random.normal(jax.random.key(0), (512, 32))

    def nearest(q):  # per-item region
        d = jnp.sum((centroids - q[None, :]) ** 2, axis=1)
        return jnp.argmin(d)

    queries = jax.random.normal(jax.random.key(1), (2048, 32))

    region = Region(
        name="nearest-centroid",
        fn=nearest,
        items=queries,
        task_flops=512 * 3 * 32,  # napkin: 512 dists × 3 ops × 32 dims
        task_bytes=512 * 32 * 4,  # streams the centroid table
        task_chain=1,
        vector=True,
    )
    report = Aira(hw=CPU_HW).advise(
        Workload("user-kernel", lambda: jax.vmap(nearest)(queries), [region])
    )
    print(report.render())
    d = report.decisions[0]
    if not d.accepted:
        print("\nregion not profitable — left serial (the gate did its job)")
        return

    got = np.asarray(d.parallel_fn())
    want = np.asarray(jax.vmap(nearest)(queries))
    assert (got == want).all()
    print(f"\nrestructured output verified on {len(want)} items; "
          f"schedule: {d.schedule.describe()}")

    # the plan is a cached, reusable artifact: execute on fresh items of
    # the same signature, and re-advising hits the plan cache
    more_queries = jax.random.normal(jax.random.key(2), (2048, 32))
    got2 = np.asarray(d.plan.execute(more_queries))
    want2 = np.asarray(jax.vmap(nearest)(more_queries))
    assert (got2 == want2).all()
    report2 = Aira(hw=CPU_HW).advise(
        Workload("user-kernel", lambda: jax.vmap(nearest)(queries), [region])
    )
    assert report2.decisions[0].plan is d.plan
    print(f"plan reused on new items; cache: {plan_cache_stats()}")


if __name__ == "__main__":
    main()
