import os
import sys

# make `repro` importable regardless of how pytest is invoked
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
# NOTE: no XLA_FLAGS here on purpose — smoke tests must see the single
# real device; sharded tests spawn subprocesses (test_sharded.py).
