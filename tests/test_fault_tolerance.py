"""Checkpoint/restart, induced node failure, elastic restore, async
save, straggler watchdog plumbing, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import Model
from repro.train import AdamW, Trainer, TrainerConfig


def _trainer(tmp, fail_at=None, total=12):
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    data = SyntheticLMData(cfg, batch=4, seq=32)
    tc = TrainerConfig(total_steps=total, ckpt_every=5, ckpt_dir=str(tmp), log_every=100)
    return Trainer(m, AdamW(lr=1e-3, warmup_steps=2, total_steps=total), data, tc,
                   fail_at_step=fail_at, log_fn=lambda s: None)


def test_induced_failure_and_bitexact_resume(tmp_path):
    # run A: fail at step 7, restart, complete
    tr = _trainer(tmp_path / "a", fail_at=7)
    with pytest.raises(RuntimeError, match="induced node failure"):
        tr.run()
    state_a, _ = tr.run()  # resumes from the step-5 checkpoint
    assert state_a.step == 12
    assert any("restored step 5" in e for e in tr.events)

    # run B: no failure — same data stream ⇒ identical final params
    tr_b = _trainer(tmp_path / "b")
    state_b, _ = tr_b.run()
    da = jax.tree.leaves(state_a.params)
    db = jax.tree.leaves(state_b.params)
    for a, b in zip(da, db):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_checkpoint_atomic_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(8.0), "b": jnp.ones((3,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [3, 4]  # keep-2 GC
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_async_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.zeros((1000, 100))}
    mgr.save(10, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 10


def test_elastic_restore_dtype_and_structure(tmp_path):
    """A checkpoint restores into a differently-typed target (the
    mesh-elastic path re-shards at load; on CPU we check structure+cast)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    target = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    restored, _ = mgr.restore(target)
    assert restored["w"].dtype == jnp.bfloat16


def test_straggler_watchdog_fires():
    import time

    from repro.train.trainer import Trainer

    tr = _trainer.__wrapped__ if hasattr(_trainer, "__wrapped__") else None
    # simulate: feed the EWMA then a slow step via monkeypatched clock
    # (structural test — the watchdog path writes an event + checkpoint)
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    data = SyntheticLMData(cfg, batch=2, seq=16)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(total_steps=6, ckpt_every=100, ckpt_dir=d,
                           straggler_factor=0.0001, log_every=100)
        t = Trainer(m, AdamW(lr=1e-3, total_steps=6), data, tc, log_fn=lambda s: None)
        t.run()
        assert any("straggler" in e for e in t.events)


def test_gradient_compression_error_feedback():
    from repro.parallel.compression import _quantize

    g = jnp.array([0.1, -0.25, 0.003, 1.0])
    scale = jnp.max(jnp.abs(g)) / 127.0
    q, err = _quantize(g, scale)
    assert q.dtype == jnp.int8
    # dequantized + residual reconstructs exactly
    np.testing.assert_allclose(np.asarray(q * scale + err), np.asarray(g), atol=1e-7)
