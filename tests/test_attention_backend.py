"""Attention-backend layer: registry resolution, block-paged kernel vs
the dense-gather reference (GQA group sizes × slot/paged layouts ×
int8-KV × verify depths, cache lengths on block boundaries), paged
gather/scatter property tests with null-block routing, no-retrace
contracts, and the measured KernelAdvisorTool gate."""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models import Model
from repro.models.attention import (
    gather_block_rows,
    scatter_block_token,
    scatter_block_tokens,
)
from repro.serve import Request, ServingEngine, SpecConfig

KEY = jax.random.key(0)


def ks(i):
    return jax.random.fold_in(KEY, i)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (6, 16), 0, cfg.vocab_size)
    return cfg, m, params, prompts


# ---------------------------------------------------------------------------
# registry: resolve once, fail loudly, per-call wins


@pytest.fixture
def clean_registry(monkeypatch):
    """Snapshot/restore the resolved-backend cache around a test."""
    monkeypatch.delenv("REPRO_ATTENTION_BACKEND", raising=False)
    saved = ops._ATTN_BACKEND
    ops.set_attention_backend(None)
    yield monkeypatch
    ops._ATTN_BACKEND = saved


def test_bad_backend_override_fails_with_choices(clean_registry):
    clean_registry.setenv("REPRO_ATTENTION_BACKEND", "warp")
    with pytest.raises(ValueError, match=r"reference.*kernel.*interpret"):
        ops.resolve_attention_backend()
    with pytest.raises(ValueError, match=r"reference.*kernel.*interpret"):
        ops.resolve_attention_backend("warp")
    with pytest.raises(ValueError, match=r"reference.*kernel.*interpret"):
        ops.set_attention_backend("warp")


def test_backend_resolution_order(clean_registry):
    # env resolves once; "auto" maps to the platform default (CPU → reference)
    clean_registry.setenv("REPRO_ATTENTION_BACKEND", "interpret")
    assert ops.resolve_attention_backend() == "interpret"
    # config override beats env; None restores env/platform resolution
    ops.set_attention_backend("reference")
    assert ops.resolve_attention_backend() == "reference"
    # per-call always wins; an explicit "auto" defers to the default
    # chain (config → env → platform), never bypassing the env override
    assert ops.resolve_attention_backend("interpret") == "interpret"
    assert ops.resolve_attention_backend("auto") == "reference"  # config override
    ops.set_attention_backend("auto")  # restores env resolution
    assert ops.resolve_attention_backend("auto") == "interpret"  # env wins


def test_bad_kernel_mode_override_fails_loudly(monkeypatch):
    saved = ops._DEFAULT_MODE
    ops._DEFAULT_MODE = None
    monkeypatch.setenv("REPRO_KERNEL_MODE", "mosaic")
    try:
        with pytest.raises(ValueError, match=r"ref.*kernel.*interpret"):
            ops.default_kernel_mode()
    finally:
        ops._DEFAULT_MODE = saved


def test_kernel_mode_resolves_once(monkeypatch):
    saved = ops._DEFAULT_MODE
    ops._DEFAULT_MODE = None
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    try:
        assert ops.default_kernel_mode() == "interpret"
        # cached: later env changes don't re-resolve mid-process
        monkeypatch.setenv("REPRO_KERNEL_MODE", "ref")
        assert ops.default_kernel_mode() == "interpret"
    finally:
        ops._DEFAULT_MODE = saved


# ---------------------------------------------------------------------------
# kernel vs dense-gather oracle (the per-layer differential)


@pytest.mark.parametrize("t", [1, 2, 4, 8])
@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 2), (4, 1)])
def test_paged_kernel_matches_oracle(t, h, kv):
    B, hd, NB, BS, MB = 3, 16, 11, 4, 5
    rng = np.random.default_rng(t * 31 + h * 7 + kv)
    q = jnp.asarray(rng.normal(size=(B, t, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, BS, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, BS, kv, hd)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, NB, size=(B, MB)), jnp.int32)
    # lengths exercise 0, a block-interior value, and an exact block
    # boundary (the mask edge lands precisely between DMA'd blocks)
    lens = jnp.asarray([0, BS * 2, BS * 3 - t][:B], jnp.int32)
    got = ops.paged_attention(q, kp, vp, tbl, lens, mode="interpret")
    want = ops.paged_attention(q, kp, vp, tbl, lens, mode="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("t", [1, 4])
def test_paged_kernel_int8_dequant_in_kernel(t):
    B, h, kv, hd, NB, BS, MB = 2, 4, 2, 16, 9, 8, 3
    rng = np.random.default_rng(t)
    q = jnp.asarray(rng.normal(size=(B, t, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, size=(NB, BS, kv, hd)), jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, size=(NB, BS, kv, hd)), jnp.int8)
    kscale = jnp.asarray(rng.uniform(0.05, 1.0, size=(NB, BS, kv)), jnp.bfloat16)
    vscale = jnp.asarray(rng.uniform(0.05, 1.0, size=(NB, BS, kv)), jnp.bfloat16)
    tbl = jnp.asarray(rng.integers(0, NB, size=(B, MB)), jnp.int32)
    lens = jnp.asarray([BS, 2 * BS - t], jnp.int32)  # one on a boundary
    got = ops.paged_attention(q, kp, vp, tbl, lens, kscale, vscale, mode="interpret")
    want = ops.paged_attention(q, kp, vp, tbl, lens, kscale, vscale, mode="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=5e-4)


def test_paged_oracle_matches_decode_attention_ref():
    """The paged oracle with the identity table and T=1 is exactly the
    dense decode oracle — pins the lengths convention (query t sees
    positions < len + t + 1) against the established reference."""
    B, h, kv, hd, Smax, BS = 2, 4, 2, 16, 32, 8
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(B, 1, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, Smax, kv, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Smax, kv, hd)), jnp.float32)
    clen = jnp.asarray([7, Smax], jnp.int32)
    mb = Smax // BS
    tbl = jnp.arange(B * mb, dtype=jnp.int32).reshape(B, mb)
    pool = lambda a: a.reshape((B * mb, BS) + a.shape[2:])
    got = ref.paged_attention_ref(q, pool(kc), pool(vc), tbl, clen - 1)
    want = ref.decode_attention_ref(q[:, 0], kc, vc, clen)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want), atol=1e-5, rtol=1e-5)


def test_ops_paged_attention_mode_contract(clean_registry):
    """The wrapper accepts the registry's own name ("reference"), fails
    loudly on bad modes, and resolves "auto" OUTSIDE the jit boundary —
    a registry change between calls is honored, not replayed from the
    first trace."""
    rng = np.random.default_rng(9)
    B, t, h, kv, hd, NB, BS, MB = 2, 1, 4, 2, 8, 5, 4, 3  # unique shapes
    q = jnp.asarray(rng.normal(size=(B, t, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(NB, BS, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(NB, BS, kv, hd)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, NB, size=(B, MB)), jnp.int32)
    lens = jnp.asarray([3, BS * 2], jnp.int32)
    a = ops.paged_attention(q, kp, vp, tbl, lens, mode="reference")
    b = ops.paged_attention(q, kp, vp, tbl, lens, mode="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match=r"reference.*kernel.*interpret"):
        ops.paged_attention(q, kp, vp, tbl, lens, mode="mosaic")
    # auto re-resolves per call: flipping the registry switches branches
    # (distinct static modes → distinct jit entries, same shapes)
    ops.set_attention_backend("reference")
    ref_out = ops.paged_attention(q, kp, vp, tbl, lens, mode="auto")
    size0 = ops._paged_attention_impl._cache_size()  # auto hit the ref trace
    ops.set_attention_backend("interpret")
    int_out = ops.paged_attention(q, kp, vp, tbl, lens, mode="auto")
    # same shapes, new static mode → a NEW trace: auto re-resolved
    assert ops._paged_attention_impl._cache_size() == size0 + 1
    np.testing.assert_allclose(
        np.asarray(ref_out), np.asarray(int_out), atol=2e-5, rtol=2e-5
    )


# ---------------------------------------------------------------------------
# gather/scatter property tests (the reference path stays honest)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), bs=st.integers(1, 5))
def test_gather_scatter_block_rows_match_python_reference(seed, bs):
    """Random block tables with null-block entries and boundary-spanning
    verify writes: gather+mask equals a per-row python reference,
    dead-row writes land in the null block ONLY, and live writes touch
    exactly the addressed (block, offset) slots."""
    rng = random.Random(seed)
    L, B, MB = 2, rng.randint(1, 4), rng.randint(2, 5)
    NB, hd = rng.randint(2, 8), 3
    null = NB  # the spare block, as PagedKVCache lays it out
    pool = np.arange(L * (NB + 1) * bs * hd, dtype=np.float32).reshape(
        L, NB + 1, bs, hd
    )
    tables = np.full((B, MB), null, np.int32)
    owned = [rng.randint(0, MB) for _ in range(B)]  # rows own a prefix; rest null
    for b in range(B):
        for j in range(owned[b]):
            tables[b, j] = rng.randrange(NB)

    got = np.asarray(gather_block_rows(jnp.asarray(pool), jnp.asarray(tables)))
    for b in range(B):
        want = np.concatenate([pool[:, tables[b, j]] for j in range(MB)], axis=1)
        np.testing.assert_array_equal(got[:, b], want)

    # single-token scatter: dead rows (no owned tail) target the null block
    tok = np.arange(L * B * hd, dtype=np.float32).reshape(L, B, hd) + 1000.0
    bid = np.array(
        [tables[b, max(owned[b] - 1, 0)] for b in range(B)], np.int32
    )
    off = np.array([rng.randrange(bs) for _ in range(B)], np.int32)
    new = np.asarray(
        scatter_block_token(jnp.asarray(pool), jnp.asarray(tok), jnp.asarray(bid), jnp.asarray(off))
    )
    expect = pool.copy()
    for b in range(B):  # later rows win colliding writes, like jax .set
        expect[:, bid[b], off[b]] = tok[:, b]
    np.testing.assert_array_equal(new, expect)
    touched = {(int(bid[b]), int(off[b])) for b in range(B)}
    unchanged = [
        (blk, o)
        for blk in range(NB + 1)
        for o in range(bs)
        if (blk, o) not in touched
    ]
    for blk, o in unchanged:
        np.testing.assert_array_equal(new[:, blk, o], pool[:, blk, o])
    for b in range(B):
        if owned[b] == 0:  # dead row: its write may only land in the null block
            assert int(bid[b]) == null

    # multi-token (verify) scatter spanning a block boundary
    T = bs + 1  # guarantees at least one boundary crossing
    start = rng.randrange(bs)
    pos = start + np.arange(T)
    rows = np.arange(L * B * T * hd, dtype=np.float32).reshape(L, B, T, hd) - 500.0
    bid2 = np.zeros((B, T), np.int32)
    off2 = np.zeros((B, T), np.int32)
    for b in range(B):
        for t in range(T):
            j = int(pos[t]) // bs
            bid2[b, t] = tables[b, j] if j < MB else null
            off2[b, t] = int(pos[t]) % bs
    new2 = np.asarray(
        scatter_block_tokens(
            jnp.asarray(pool), jnp.asarray(rows), jnp.asarray(bid2), jnp.asarray(off2)
        )
    )
    expect2 = pool.copy()
    for b in range(B):
        for t in range(T):
            expect2[:, bid2[b, t], off2[b, t]] = rows[:, b, t]
    np.testing.assert_array_equal(new2, expect2)


# ---------------------------------------------------------------------------
# serve-level differentials: kernel backend ≡ reference backend


def _trace(prompts, lens, budgets, eos=None, eos_req=None):
    return [
        Request(
            prompt=np.asarray(prompts[i, : lens[i]]),
            max_new_tokens=int(budgets[i]),
            arrival_time=0.01 * i,
            eos_id=eos if i == eos_req else None,
        )
        for i in range(len(lens))
    ]


def _serve_both_backends(m, params, prompts, *, kv_layout, int8=False, spec=None, seed=2):
    cfg = m.cfg
    if int8:
        m = Model(dataclasses.replace(cfg, kv_quant=True))
        params, _ = m.init(jax.random.key(0))
    rng = np.random.default_rng(seed)
    n = 4
    lens = rng.integers(3, 16, size=n)
    # prompt lengths landing exactly on block boundaries included
    lens[0] = 8
    budgets = rng.integers(2, 7, size=n)
    eng = ServingEngine(m, params, max_seq=64, kv_layout=kv_layout, block_size=4)
    outs = {}
    for backend in ("reference", "interpret"):
        reqs = _trace(prompts, lens, budgets)
        sched = eng.scheduler(3, spec=spec, attention_backend=backend)
        out = sched.run(reqs)
        if kv_layout == "paged":
            sched.kv.check_invariants()
        outs[backend] = [np.asarray(out[r.rid]) for r in reqs]
        assert all(r.finished for r in reqs)
    for a, b in zip(outs["reference"], outs["interpret"]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_serve_kernel_backend_token_identical(served, kv_layout):
    """Randomized open-loop trace through the interpret-mode kernel
    backend decodes token-for-token identical to the reference backend
    — no dense gather on the kernel path (both layouts)."""
    _, m, params, prompts = served
    _serve_both_backends(m, params, prompts, kv_layout=kv_layout)


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
def test_serve_kernel_backend_token_identical_int8(served, kv_layout):
    """int8-KV: per-vector scales ride their own blocks and dequantize
    in-kernel; the token stream still matches the reference backend."""
    _, m, params, prompts = served
    _serve_both_backends(m, params, prompts, kv_layout=kv_layout, int8=True)


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("k", [2, 4, 8])
def test_verify_kernel_backend_token_identical(served, kv_layout, k):
    """Speculative serving through the kernel backend (the K+1-query
    verify variant) stays token-identical to the reference backend at
    every depth — acceptance is data, the kernel trace is per depth."""
    _, m, params, prompts = served
    _serve_both_backends(
        m, params, prompts, kv_layout=kv_layout,
        spec=SpecConfig(k=k, drafter="ngram"),
    )


def test_verify_kernel_backend_model_drafter(served):
    """The draft-model stream (its own slot pool) rides the kernel
    backend too: target verify and drafter decode both dispatch through
    the registry, and the stream stays token-identical."""
    cfg, m, params, prompts = served
    dm = Model(dataclasses.replace(cfg, num_layers=1))
    dparams, _ = dm.init(jax.random.key(7))
    _serve_both_backends(
        m, params, prompts, kv_layout="paged",
        spec=SpecConfig(k=4, drafter="model", draft_model=dm, draft_params=dparams),
    )


# ---------------------------------------------------------------------------
# trace discipline


def test_paged_kernel_step_no_retrace_on_table_or_length_changes(served):
    """One jit trace serves any block layout and live set: changing
    only ``cache_len``/``block_tables`` values (same shapes) must not
    retrace the kernel-backend paged step."""
    _, m, params, _ = served
    traces = []

    def counted(params, pool, tables, lens, tok):
        traces.append(1)
        return m.decode_step_paged(params, pool, tables, lens, tok, backend="interpret")

    step = jax.jit(counted)
    B, bs, nb, mb = 2, 4, 12, 4
    pool = m.init_paged_cache(nb + 1, bs)
    tok = jnp.zeros((B, 1), jnp.int32)
    tables = jnp.asarray([[0, 1, nb, nb], [2, 3, nb, nb]], jnp.int32)
    lens = jnp.asarray([3, 5], jnp.int32)
    _, pool = step(params, pool, tables, lens, tok)
    _, pool = step(params, pool, tables + 1, lens + 1, tok)
    _, pool = step(params, pool, jnp.flip(tables, 0), jnp.asarray([0, 8], jnp.int32), tok)
    assert len(traces) == 1, "tables/lengths must be data, not shape"


def test_sharded_callers_stay_on_reference_path():
    """With sharding rules set the kernel dispatch is bypassed — the
    kernel is not SPMD-partitioned, so the seq-sharded flash-decode
    reference semantics must keep serving those callers. Pinned by
    bitwise equality with the explicit reference path (the kernel path
    would differ in accumulation order)."""
    from repro.models.attention import decode_attention

    rng = np.random.default_rng(3)
    B, h, kv, hd, Smax = 2, 4, 2, 16, 32
    q = jnp.asarray(rng.normal(size=(B, 1, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, Smax, kv, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Smax, kv, hd)), jnp.float32)
    clen = jnp.asarray([5, 17], jnp.int32)
    want = decode_attention(q, kc, vc, clen, backend="reference")
    got = decode_attention(q, kc, vc, clen, rules=object(), backend="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_backend_degenerate_max_seq_falls_back_to_reference(served):
    """A (near-)prime max_seq has no usable identity-table tiling: the
    kernel backend keeps the semantics by taking the reference numerics
    for that shape instead of a single-token-block grid."""
    from repro.models.attention import _dense_block_size

    assert _dense_block_size(64) == 64
    assert _dense_block_size(512) == 256
    assert _dense_block_size(257) == 1  # prime → degenerate → fallback
    _, m, params, prompts = served
    eng = ServingEngine(m, params, max_seq=37, attention_backend="interpret")
    out = eng.generate(prompts[:2, :5], n_steps=3)
    ref = ServingEngine(m, params, max_seq=37).generate(prompts[:2, :5], n_steps=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_backend_override_rejected_under_decode_plan(served):
    """A decode plan's per-request fn binds the engine backend at
    region-advise time; a different per-call override must fail loudly
    instead of silently running (and mislabeling) the old backend."""
    from repro.core import Aira, Workload

    _, m, params, prompts = served
    eng = ServingEngine(m, params, max_seq=64)
    region = eng.decode_region(prompts[:2, :8], force=True)
    d = Aira().advise(Workload("w", lambda: None, [region])).decisions[0]
    assert d.accepted
    eng.set_decode_plan(d.plan)
    with pytest.raises(ValueError, match="re-advise"):
        eng.scheduler(2, attention_backend="interpret")
    eng.scheduler(2)  # engine's own backend still fine


def test_engine_step_family_cached_per_backend(served):
    """Switching backends on one engine reuses each backend's jitted
    family — no cross-backend clobbering, no rebuild on re-request."""
    _, m, params, _ = served
    eng = ServingEngine(m, params, max_seq=32)
    ref_fns = eng._step_fns("reference")
    int_fns = eng._step_fns("interpret")
    assert ref_fns is not int_fns
    assert eng._step_fns("reference")["decode"] is ref_fns["decode"]
    eng._paged_fns("interpret")
    assert "decode_paged" in eng._steps["interpret"]
    assert "decode_paged" not in eng._steps["reference"]


# ---------------------------------------------------------------------------
# the measured backend gate


def test_kernel_advisor_prices_measured_cost():
    from repro.core.tools import KernelAdvisorTool, KernelMeasurement

    tool = KernelAdvisorTool()
    # kernel clearly faster → chosen, gain quoted vs reference
    m = KernelMeasurement.make("dense", "paged", 0, {"reference": 2.0, "kernel": 1.0})
    backend, gain, log = tool.choose(m)
    assert backend == "kernel" and gain == pytest.approx(1.0)
    assert "paged" in log and "kernel" in log
    # inside the threshold → don't switch (measured, not assumed)
    m = KernelMeasurement.make("dense", "slot", 0, {"reference": 1.0, "kernel": 0.99})
    assert tool.choose(m)[0] == "reference"
    # interpret slower than reference (CPU CI) → reference
    m = KernelMeasurement.make("dense", "slot", 4, {"reference": 1.0, "interpret": 3.0})
    backend, gain, _ = tool.choose(m)
    assert backend == "reference" and gain == 0.0
    with pytest.raises(ValueError, match="reference"):
        KernelMeasurement.make("dense", "slot", 0, {"kernel": 1.0})


def test_kernel_advisor_is_silent_for_compute_regions():
    """As a pipeline stage the tool SKIPs (no stage-log line) unless a
    region carries a kernel measurement — golden decisions untouched;
    a measured region gets a 'kernel:' line with the chosen backend."""
    from repro.core import Aira, Workload
    from repro.core.adviser import Region
    from repro.core.overlap_model import CPU_HW
    from repro.core.tools import KernelMeasurement

    def region(name):
        return Region(
            name, lambda x: x * 2.0, jnp.arange(1024, dtype=jnp.float32),
            task_flops=100.0, task_bytes=512.0, task_chain=16,
        )

    r1 = region("plain")
    d = Aira(hw=CPU_HW).advise(Workload("w", lambda: None, [r1])).decisions[0]
    assert d.accepted
    assert not any("kernel" in line for line in d.stage_log)

    r2 = region("measured")
    r2.kernel_measurement = KernelMeasurement.make(
        "dense", "paged", 0, {"reference": 2.0, "kernel": 0.8}
    )
    d2 = Aira(hw=CPU_HW).advise(Workload("w", lambda: None, [r2])).decisions[0]
    assert any(
        line.startswith("kernel:") and "→ kernel" in line for line in d2.stage_log
    )
