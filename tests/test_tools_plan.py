"""Tool pipeline + plan layer: golden decisions, policy seam, plan cache
(no retrace on repeated advise/execute), suite advisory, serving hook."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Aira,
    RecordingPolicy,
    Region,
    ReplayPolicy,
    SpecPolicy,
    ToolPipeline,
    Workload,
    advise_suite,
    clear_plan_cache,
    plan_cache_stats,
)
from repro.core.overlap_model import CPU_HW
from repro.core.tools import CONTINUE, STOP, StageResult

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_aira_decisions.json")


# ---------------------------------------------------------------------------
# golden: the pipeline must reproduce the pre-refactor adviser's decisions


def test_golden_suite_decisions():
    """Every benchmark's accept/reject decision (and chosen schedule)
    matches the checked-in pre-refactor baseline."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    suite = advise_suite(hw=CPU_HW)
    assert set(suite) == set(golden)
    for name, want in golden.items():
        d = suite[name].decision
        assert d.accepted == want["accepted"], (name, d.stage_log)
        assert d.schedule.strategy == want["strategy"], name
        assert d.schedule.granularity == want["granularity"], name
        np.testing.assert_allclose(
            d.predicted_gain, want["predicted_gain"], atol=1e-5, err_msg=name
        )
        # accepted decisions carry a cached plan; rejected ones do not
        assert (suite[name].plan is not None) == want["accepted"], name


def test_suite_advise_twice_hits_plan_cache():
    clear_plan_cache()
    s1 = advise_suite(hw=CPU_HW)
    stats1 = plan_cache_stats()
    assert stats1["misses"] > 0
    s2 = advise_suite(hw=CPU_HW)
    stats2 = plan_cache_stats()
    assert stats2["misses"] == stats1["misses"]  # no new plan builds
    assert stats2["hits"] >= stats1["hits"] + stats1["misses"]
    for name in s1:
        if s1[name].plan is not None:
            assert s2[name].plan is s1[name].plan, name


# ---------------------------------------------------------------------------
# plan cache: repeated advise + execute must not retrace


def _accepted_region(fn, items, name="trace-count"):
    # chain-heavy VPU microtask: comfortably inside the smt2 band
    return Region(
        name, fn, items, task_flops=100.0, task_bytes=512.0, task_chain=16
    )


def test_plan_cache_no_second_jit_trace():
    clear_plan_cache()
    traces = []

    def fn(x):  # python side effect runs at TRACE time only
        traces.append(1)
        return 2.0 * x + 1.0

    items = jnp.arange(4096, dtype=jnp.float32)
    aira = Aira(hw=CPU_HW)

    d1 = aira.advise(Workload("w", lambda: None, [_accepted_region(fn, items)])).decisions[0]
    assert d1.accepted and d1.plan is not None
    jax.block_until_ready(d1.plan.execute(items))
    n_traces = len(traces)
    assert n_traces >= 1

    # second advisory run: same region signature → cached plan, and
    # executing it again does not retrace the restructured program
    d2 = aira.advise(Workload("w", lambda: None, [_accepted_region(fn, items)])).decisions[0]
    assert d2.plan is d1.plan
    jax.block_until_ready(d2.plan.execute(items))
    jax.block_until_ready(d2.parallel_fn())
    assert len(traces) == n_traces, "plan execution retraced the region"


def test_plan_executes_on_fresh_same_signature_items():
    clear_plan_cache()
    fn = lambda x: (x * 3.0).sum()
    items = jnp.arange(256, dtype=jnp.float32).reshape(64, 4)
    aira = Aira(hw=CPU_HW)
    d = aira.advise(Workload("w", lambda: None, [_accepted_region(fn, items)])).decisions[0]
    assert d.accepted
    fresh = items + 7.0
    np.testing.assert_allclose(
        np.asarray(d.plan.execute(fresh)),
        np.asarray(jax.vmap(fn)(fresh)),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# policy seam


def test_recording_then_replay_policy():
    fn = lambda x: x * 2.0
    items = jnp.arange(1024, dtype=jnp.float32)
    region = _accepted_region(fn, items, name="rec")

    rec = RecordingPolicy(SpecPolicy())
    d1 = Aira(hw=CPU_HW, policy=rec).advise(Workload("w", lambda: None, [region])).decisions[0]
    assert d1.accepted
    stages = [stage for (_, stage, _, _) in rec.record]
    # "speculate" and "kernel" ride in DEFAULT_TOOLS but SKIP (silently)
    # for compute regions — they still pass through the policy seat
    assert stages == [
        "profile", "static", "dynamic", "simulate", "restructure", "speculate",
        "kernel",
    ]
    assert all(action == CONTINUE for (_, _, _, action) in rec.record)

    d2 = Aira(hw=CPU_HW, policy=ReplayPolicy(rec.record)).advise(
        Workload("w", lambda: None, [region])
    ).decisions[0]
    assert d2.accepted == d1.accepted
    assert d2.schedule.granularity == d1.schedule.granularity


def test_replay_policy_can_override_verdicts():
    """A replayed STOP at the simulate stage rejects a region the spec
    rules would accept — the decision seat is genuinely swappable."""
    fn = lambda x: x * 2.0
    items = jnp.arange(1024, dtype=jnp.float32)
    region = _accepted_region(fn, items, name="override")
    record = [
        ("override", "profile", "pass", CONTINUE),
        ("override", "static", "pass", CONTINUE),
        ("override", "dynamic", "skip", CONTINUE),
        ("override", "simulate", "pass", STOP),
    ]
    d = Aira(hw=CPU_HW, policy=ReplayPolicy(record)).advise(
        Workload("w", lambda: None, [region])
    ).decisions[0]
    assert not d.accepted
    assert d.schedule is not None  # simulate ran before the stop


def test_replay_policy_detects_divergence():
    fn = lambda x: x * 2.0
    items = jnp.arange(1024, dtype=jnp.float32)
    region = _accepted_region(fn, items, name="diverge")
    record = [("some-other-region", "profile", "pass", CONTINUE)]
    with pytest.raises(ValueError, match="ReplayPolicy"):
        Aira(hw=CPU_HW, policy=ReplayPolicy(record)).advise(
            Workload("w", lambda: None, [region])
        )


def test_pipeline_force_overrides_policy_stop():
    table = jnp.zeros((64,))

    def fn(i):  # shared scatter, no trace → dynamic reject
        return table.at[i].add(1.0).sum()

    items = jnp.arange(32, dtype=jnp.int32)
    region = Region("forced", fn, items, task_flops=64, task_bytes=512,
                    task_chain=4, force=True)
    d = Aira(hw=CPU_HW).advise(Workload("w", lambda: None, [region])).decisions[0]
    assert d.accepted
    assert any("force=True" in s for s in d.stage_log)


# ---------------------------------------------------------------------------
# serving: the decode step is an advisable workload


def test_serving_decode_plan_matches_plain_decode():
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine

    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    prompts = jnp.ones((4, 8), jnp.int32)

    eng = ServingEngine(m, params, max_seq=64)
    region = eng.decode_region(prompts, force=True)
    d = Aira().advise(Workload("serve", lambda: None, [region])).decisions[0]
    assert d.accepted and d.plan is not None
    # the honest outcome: batched decode is bandwidth-bound, the gate
    # says no, and the latency-critical deployment force-applies
    assert any("force=True" in s for s in d.stage_log)

    out_plain = ServingEngine(m, params, max_seq=64).generate(prompts, n_steps=4)
    eng2 = ServingEngine(m, params, max_seq=64, decode_plan=d.plan)
    out_plan = eng2.generate(prompts, n_steps=4)
    np.testing.assert_array_equal(np.asarray(out_plain), np.asarray(out_plan))
    assert eng2.stats.percentile(50) > 0


def test_two_engines_do_not_alias_plans():
    """Same region name + item shapes but different params: the content
    fingerprint in the plan key must keep the plans (and weights) apart."""
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine

    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    p1, _ = m.init(jax.random.key(0))
    p2, _ = m.init(jax.random.key(99))
    prompts = jnp.ones((4, 8), jnp.int32)
    clear_plan_cache()
    e1 = ServingEngine(m, p1, max_seq=64)
    e2 = ServingEngine(m, p2, max_seq=64)
    d1 = Aira().advise(
        Workload("s", lambda: None, [e1.decode_region(prompts, force=True)])
    ).decisions[0]
    d2 = Aira().advise(
        Workload("s", lambda: None, [e2.decode_region(prompts, force=True)])
    ).decisions[0]
    assert d1.plan is not d2.plan
    e2.set_decode_plan(d2.plan)
    out_plan = e2.generate(prompts, n_steps=3)
    out_plain = ServingEngine(m, p2, max_seq=64).generate(prompts, n_steps=3)
    np.testing.assert_array_equal(np.asarray(out_plan), np.asarray(out_plain))


def test_serving_rejects_sum_combine_plan():
    from repro.core.plan import plan_for
    from repro.configs import get_config
    from repro.models import Model
    from repro.serve import ServingEngine

    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    eng = ServingEngine(m, params, max_seq=64)
    bad = plan_for("bad", lambda x: x, jnp.arange(4.0), granularity=1, combine="sum")
    with pytest.raises(ValueError, match="stack"):
        eng.set_decode_plan(bad)
