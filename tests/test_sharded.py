"""Distributed-correctness tests. Each test runs in a subprocess with
xla_force_host_platform_device_count=8 so the main pytest process keeps
the single real device (per the dry-run isolation rule)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, cwd=ROOT,
        timeout=600,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr}\nstdout:\n{r.stdout}"
    assert "PASS" in r.stdout, r.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P
try:
    mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
except AttributeError:  # jax 0.4.x: no AxisType
    mesh = jax.make_mesh((2, 4), ("data", "model"))
"""


def test_moe_dispatch_matches_reference():
    _run(HEADER + """
from repro.configs import get_config
from repro.models.moe import _moe_reference, init_moe, moe_block
from repro.parallel.sharding import ShardingRules
cfg = get_config("granite-moe-1b-a400m").reduced()
params, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
rules = ShardingRules(mesh, cfg)
with mesh:
    y_sh, aux_sh = jax.jit(lambda x, p: moe_block(x, p, cfg, rules, path="dispatch"))(x, params)
y_ref, aux_ref = moe_block(x, params, cfg, None)
np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref), atol=2e-4, rtol=2e-4)
print("PASS")
""")


def test_moe_dense_path_matches_reference():
    _run(HEADER + """
from repro.configs import get_config
from repro.models.moe import init_moe, moe_block
from repro.parallel.sharding import ShardingRules
cfg = get_config("granite-moe-1b-a400m").reduced()
params, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (8, 1, cfg.d_model))
rules = ShardingRules(mesh, cfg)
with mesh:
    y_sh, _ = jax.jit(lambda x, p: moe_block(x, p, cfg, rules, path="dense"))(x, params)
# dense path computes ALL experts' masked contributions — compare against
# an explicit dense-mixture oracle
import jax.numpy as jnp2
from repro.models.moe import _route
x2 = x.reshape(-1, cfg.d_model)
gates, idx, _ = _route(x2, params["router"], cfg.top_k)
h = jax.nn.silu(jnp.einsum("td,edf->etf", x2, params["we1"]))
h = h * jnp.einsum("td,edf->etf", x2, params["we3"])
ye = jnp.einsum("etf,efd->etd", h, params["we2"])
gmat = jnp.zeros((x2.shape[0], cfg.n_experts)).at[jnp.arange(x2.shape[0])[:,None], idx].add(gates)
want = jnp.einsum("etd,te->td", ye, gmat).reshape(x.shape)
np.testing.assert_allclose(np.asarray(y_sh), np.asarray(want), atol=2e-4, rtol=2e-4)
print("PASS")
""")


def test_ring_collectives_match_dense():
    _run(HEADER + """
from repro.parallel.collectives import ring_allgather_matmul, matmul_reducescatter
T, D, F = 32, 16, 24
x = jax.random.normal(jax.random.key(0), (T, D))
w1 = jax.random.normal(jax.random.key(1), (D, F))
w2 = jax.random.normal(jax.random.key(2), (F, D))
agm = shard_map(lambda xl, wl: ring_allgather_matmul(xl, wl, "model"),
                mesh=mesh, in_specs=(P("model", None), P(None, "model")),
                out_specs=P(None, "model"), check_vma=False)
np.testing.assert_allclose(np.asarray(agm(x, w1)), np.asarray(x @ w1), atol=1e-5)
h = jax.random.normal(jax.random.key(3), (T, F))
rsm = shard_map(lambda hl, wl: matmul_reducescatter(hl, wl, "model"),
                mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
                out_specs=P("model", None), check_vma=False)
np.testing.assert_allclose(np.asarray(rsm(h, w2)), np.asarray(h @ w2), atol=1e-5)
print("PASS")
""")


def test_distributed_softmax_combine():
    """Per-rank flash partials over a kv-sequence split combine to the
    exact global softmax-weighted sum (DESIGN.md §5 derivation)."""
    _run(HEADER + """
from repro.parallel.collectives import distributed_softmax
B, H, S, d = 2, 4, 32, 8
logits = jax.random.normal(jax.random.key(0), (B, H, S)) * 4.0
v = jax.random.normal(jax.random.key(1), (B, H, S, d))
want = jnp.einsum("bhs,bhsd->bhd", jax.nn.softmax(logits, axis=-1), v)
def local(lg, vl):
    m = lg.max(-1)
    p = jnp.exp(lg - m[..., None])
    acc = jnp.einsum("bhs,bhsd->bhd", p, vl)
    return distributed_softmax(m, p.sum(-1), acc, "model")
fn = shard_map(local, mesh=mesh,
               in_specs=(P(None, None, "model"), P(None, None, "model", None)),
               out_specs=P(), check_vma=False)
np.testing.assert_allclose(np.asarray(fn(logits, v)), np.asarray(want),
                           atol=1e-5, rtol=1e-5)
print("PASS")
""")


def test_distributed_softmax_empty_shard():
    """Empty-shard guard (DESIGN.md §5): a rank whose kv-sequence shard
    holds zero valid positions reports m = -inf / l = 0 and must
    contribute scale 0 — not NaN — to the combine; when every rank is
    empty the combine returns exact zeros."""
    _run(HEADER + """
from repro.parallel.collectives import distributed_softmax
B, H, S, d = 2, 3, 32, 8
sh = S // 4  # per-rank shard on the 4-way "model" axis
logits = jax.random.normal(jax.random.key(0), (B, H, S)) * 4.0
v = jax.random.normal(jax.random.key(1), (B, H, S, d))
# ranks 1..3 fully masked: only the first shard's positions are valid
valid = jnp.arange(S) < sh
want = jnp.einsum("bhs,bhsd->bhd",
                  jax.nn.softmax(jnp.where(valid, logits, -jnp.inf), axis=-1), v)
def local(lg, vl, keep):
    lg = jnp.where(keep, lg, -jnp.inf)  # a fully-masked shard: m = -inf
    m = lg.max(-1)
    p = jnp.where(keep, jnp.exp(lg - m[..., None]), 0.0)
    acc = jnp.einsum("bhs,bhsd->bhd", p, vl)
    return distributed_softmax(m, p.sum(-1), acc, "model")
fn = shard_map(local, mesh=mesh,
               in_specs=(P(None, None, "model"), P(None, None, "model", None),
                         P("model")),
               out_specs=P(), check_vma=False)
out = np.asarray(jax.jit(fn)(logits, v, valid))
assert not np.isnan(out).any(), "empty shards must not poison the combine"
np.testing.assert_allclose(out, np.asarray(want), atol=1e-5, rtol=1e-5)
# every rank empty -> the 0/0 row returns exact zeros, not NaN
out0 = np.asarray(jax.jit(fn)(logits, v, jnp.zeros(S, bool)))
np.testing.assert_array_equal(out0, np.zeros_like(out0))
print("PASS")
""")


def test_pipeline_two_stage():
    _run(HEADER.replace('(2, 4), ("data", "model")', '(2, 2, 2), ("pod", "data", "model")').replace("*2", "*3") + """
from repro.parallel.pipeline import pipelined_apply
L, D, B = 4, 8, 16
Ws = jax.random.normal(jax.random.key(0), (L, D, D)) * 0.3
def layer_fn(sp, x):
    def bd(x, w): return jnp.tanh(x @ w), None
    y, _ = jax.lax.scan(bd, x, sp)
    return y
x = jax.random.normal(jax.random.key(1), (B, D))
want = layer_fn(Ws[2:], layer_fn(Ws[:2], x))
got = pipelined_apply(layer_fn, Ws.reshape(2, 2, D, D), x, mesh=mesh, n_micro=4)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
print("PASS")
""")


def test_compressed_psum_pod_axis():
    _run(HEADER.replace('(2, 4), ("data", "model")', '(2, 2, 2), ("pod", "data", "model")').replace("*2", "*3") + """
from repro.parallel.compression import compressed_psum
g = jax.random.normal(jax.random.key(2), (64,))
fn = shard_map(lambda gl, el: compressed_psum(gl, "pod", el),
               mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False)
mean_g, err = fn(g, jnp.zeros_like(g))
# replicated input → mean == dequantized g, residual == quantization error
np.testing.assert_allclose(np.asarray(mean_g + err), np.asarray(g), atol=1e-6)
assert float(jnp.abs(err).max()) < float(jnp.abs(g).max()) / 64
print("PASS")
""")


def test_sharded_train_step_matches_single_device():
    _run(HEADER + """
from repro.configs import get_config
from repro.models import Model
from repro.data import SyntheticLMData
from repro.train import AdamW, make_train_step
from repro.train.optimizer import OptState
cfg = get_config("smollm-135m").reduced()
data = SyntheticLMData(cfg, batch=4, seq=32)
batch = data.batch_at(0)
opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=10)

# single-device
m1 = Model(cfg)
params, axes = m1.init(jax.random.key(0))
p1, o1, met1 = jax.jit(make_train_step(m1, opt))(params, opt.init(params), batch)

# sharded
m2 = Model(cfg, mesh=mesh)
rules = m2.rules
pshard = rules.tree_shardings(params, axes)
with mesh:
    step = jax.jit(make_train_step(m2, opt))
    p2, o2, met2 = step(params, opt.init(params), batch)
assert abs(float(met1["loss"]) - float(met2["loss"])) < 5e-3, (met1["loss"], met2["loss"])
d = max(float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 5e-2, d
print("PASS")
""")
