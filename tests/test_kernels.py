"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py),
executed in Pallas interpret mode (kernel body runs on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def ks(i):
    return jax.random.fold_in(KEY, i)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (32, 64, 48, 16, 16, 16),
    (64, 128, 96, 32, 64, 32),
    (128, 256, 128, 64, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_relic_matmul(m, k, n, bm, bk, bn, dtype):
    x = jax.random.normal(ks(1), (m, k), dtype)
    w = jax.random.normal(ks(2), (k, n), dtype)
    out = ops.matmul(x, w, bm=bm, bk=bk, bn=bn, mode="interpret")
    want = ref.matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,k,n", [(1, 128, 64), (8, 256, 128)])
def test_relic_gemv(b, k, n):
    x = jax.random.normal(ks(3), (b, k), jnp.float32)
    w = jax.random.normal(ks(4), (k, n), jnp.float32)
    out = ops.gemv(x, w, bk=64, bn=32, mode="interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, w)), atol=1e-4)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,hd,bq,bk", [
    (1, 64, 4, 4, 16, 32, 32),     # MHA
    (2, 128, 8, 4, 32, 32, 64),    # GQA g=2
    (2, 128, 8, 2, 32, 64, 32),    # GQA g=4
    (1, 256, 4, 1, 64, 64, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, h, kv, hd, bq, bk, dtype):
    q = jax.random.normal(ks(5), (b, s, h, hd), dtype)
    k = jax.random.normal(ks(6), (b, s, kv, hd), dtype)
    v = jax.random.normal(ks(7), (b, s, kv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bk, mode="interpret")
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("b,h,kv,hd,smax,bk", [
    (2, 8, 4, 32, 256, 64),
    (1, 4, 4, 64, 128, 128),
    (4, 16, 2, 16, 512, 256),
])
def test_decode_attention(b, h, kv, hd, smax, bk):
    q = jax.random.normal(ks(8), (b, h, hd), jnp.float32)
    kc = jax.random.normal(ks(9), (b, smax, kv, hd), jnp.float32)
    vc = jax.random.normal(ks(10), (b, smax, kv, hd), jnp.float32)
    clen = jax.random.randint(ks(11), (b,), 1, smax + 1)
    out = ops.decode_attention(q, kc, vc, clen, bk=bk, mode="interpret")
    want = ref.decode_attention_ref(q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,hd,n,chunk", [
    (1, 32, 2, 16, 8, 8),
    (2, 64, 4, 16, 16, 16),
    (1, 128, 2, 32, 8, 32),
])
def test_ssd_scan(b, s, h, hd, n, chunk):
    xh = jax.random.normal(ks(12), (b, s, h, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks(13), (b, s, h)))
    a = jnp.exp(-dt * 0.7)
    bb = jax.random.normal(ks(14), (b, s, n)) * 0.3
    cc = jax.random.normal(ks(15), (b, s, n)) * 0.3
    out = ops.ssd(xh, a, bb, cc, dt, chunk=chunk, mode="interpret")
    want = ref.ssd_ref(xh, a, bb, cc, dt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_ssd_chunked_matches_model_path():
    """models/ssm chunk scan == sequential oracle (same math, diff code)."""
    from repro.models.ssm import _ssd_chunk_scan

    b, s, h, hd, n = 2, 96, 4, 16, 8
    xh = jax.random.normal(ks(16), (b, s, h, hd)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks(17), (b, s, h)))
    a = jnp.exp(-dt * 0.7)
    bb = jax.random.normal(ks(18), (b, s, n)) * 0.3
    cc = jax.random.normal(ks(19), (b, s, n)) * 0.3
    got, _ = _ssd_chunk_scan(xh, a, bb, cc, dt, chunk=16)
    want = ref.ssd_ref(xh, a, bb, cc, dt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-3)


def test_vmem_budget_guard():
    with pytest.raises(ValueError):
        ops.check_vmem({"x": 20 * 2**20})


def test_triangular_blocking_matches_masked():
    """cfg.causal_blocking='triangular' (unrolled causal prefix blocks,
    ~½ the FLOPs) must equal the masked chunked path."""
    from repro.models.attention import gqa_attention

    b, s, h, kv, hd = 2, 128, 4, 2, 16
    q = jax.random.normal(ks(20), (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks(21), (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks(22), (b, s, kv, hd), jnp.float32)
    a = gqa_attention(q, k, v, chunk=32, blocking="masked")
    t = gqa_attention(q, k, v, chunk=32, blocking="triangular")
    np.testing.assert_allclose(np.asarray(a), np.asarray(t), atol=2e-5, rtol=2e-5)
