"""Serving flight recorder (serve/telemetry.py, DESIGN.md §8): the
linear-interpolation quantile against numpy.percentile, registry
windows/exposition, the tracer's ring bound and Chrome-trace schema,
the hard off-switch (telemetry on == off token streams, no events when
disabled), trace well-formedness over random open-loop traffic
(exactly one terminal event per admitted request, step/phase spans
nest), the ServeStats→registry refactor's golden ``serving_summary``
schema, XLA-annotation no-op smoke, and the adviser audit trail
(advisor decisions + ToolPipeline stage spans land in the trace with
their priced inputs)."""
import json
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_config
from repro.models import Model
from repro.serve import Request, ServingEngine, SpecConfig
from repro.serve.telemetry import (
    TID_ADVISER,
    TID_STEP,
    MetricsRegistry,
    Telemetry,
    Tracer,
    quantile,
    validate_chrome_trace,
)

_STATE: dict = {}


def _model_state():
    """Lazy module singleton (not a fixture: the hypothesis stub calls
    property tests with drawn args only, so they can't take fixtures)."""
    if not _STATE:
        cfg = get_config("smollm-135m").reduced()
        m = Model(cfg)
        params, _ = m.init(jax.random.key(0))
        eng = ServingEngine(m, params, max_seq=64, kv_layout="paged", block_size=8)
        _STATE["v"] = (cfg, m, params, eng)
    return _STATE["v"]


@pytest.fixture(scope="module")
def served():
    return _model_state()


def _workload(vocab, specs=((8, 4), (12, 6), (8, 5), (16, 3)), arrival=0.0):
    rng = np.random.default_rng(7)
    return [
        Request(
            prompt=rng.integers(0, vocab, size=n).astype(np.int32),
            max_new_tokens=t, arrival_time=arrival * i,
        )
        for i, (n, t) in enumerate(specs)
    ]


# ---------------------------------------------------------------------------
# quantile: linear interpolation == numpy.percentile default


def test_quantile_matches_numpy_percentile():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 50, 101):
        vals = rng.normal(size=n).tolist()
        for p in (0.0, 1.0, 37.5, 50.0, 90.0, 99.0, 100.0):
            assert quantile(vals, p) == pytest.approx(
                float(np.percentile(vals, p)), abs=1e-12
            ), (n, p)


def test_quantile_interpolates_not_nearest_rank():
    # p99 over 10 samples must land BETWEEN the top two order
    # statistics, not collapse to the max
    vals = list(range(10))
    assert 8.0 < quantile(vals, 99.0) < 9.0
    assert quantile([], 50.0) == 0.0


def test_serve_stats_percentile_uses_quantile(served):
    from repro.serve import ServeStats

    stats = ServeStats()
    stats.step_ms.extend([1.0, 2.0, 3.0, 10.0])
    assert stats.percentile(50) == pytest.approx(float(np.percentile([1, 2, 3, 10], 50)))
    assert stats.percentile(99) == pytest.approx(float(np.percentile([1, 2, 3, 10], 99)))
    assert stats.percentile(50, "ttft_ms") == 0.0  # empty series


# ---------------------------------------------------------------------------
# metrics registry: counters/gauges/series, windows, exposition, reset


def test_registry_windows_and_reset_in_place():
    reg = MetricsRegistry(window=8)
    c = reg.counter("x.count")
    g = reg.gauge("x.gauge")
    s = reg.series("x.series")
    for i in range(12):
        c.inc(2.0)
        g.set(float(i))
        s.append(float(i))
        reg.tick()
    assert reg.ticks == 12
    assert reg.window_delta("x.count", 4) == 8.0
    assert reg.window_delta("x.count", 100) == pytest.approx(c.value)  # ring-capped
    assert reg.window_mean("x.gauge", 4) == pytest.approx((8 + 9 + 10 + 11) / 4)
    assert reg.series_quantile("x.series", 50.0, 4) == pytest.approx(9.5)
    assert reg.window_delta("missing", 4) == 0.0
    # reset is in place: cached handles survive
    reg.reset()
    assert reg.ticks == 0 and c.value == 0.0 and g.value is None and not s
    c.inc()
    assert reg.counter("x.count").value == 1.0
    assert reg.counter("x.count") is c


def test_window_summary_schema():
    reg = MetricsRegistry()
    summary = reg.window_summary(8)
    for key in (
        "window", "ticks", "acceptance_rate", "proposed", "accepted",
        "spec_steps", "p50_draft_ms", "p50_verify_ms",
        "queue_depth", "active", "pool_occupancy", "pool_free_blocks",
        "step_cost_ms", "p99_step_ms", "admitted", "preemptions",
        "rejected", "prefix_hit_rate", "chunk_utilization",
        "alloc_rate", "evict_rate", "park_rate", "retraces",
    ):
        assert key in summary, key
    assert summary["window"] == 0  # no ticks yet


def test_prometheus_and_snapshot_smoke():
    reg = MetricsRegistry()
    reg.counter("pool.alloc").inc(3)
    reg.gauge("sched.queue_depth").set(2.0)
    reg.series("serve.step_ms").extend([1.0, 2.0])
    snap = reg.snapshot()
    assert snap["counters"]["pool.alloc"] == 3.0
    assert snap["gauges"]["sched.queue_depth"] == 2.0
    assert snap["series"]["serve.step_ms"]["count"] == 2
    text = reg.prometheus_text()
    assert "# TYPE pool_alloc counter" in text
    assert "pool_alloc 3" in text
    assert 'serve_step_ms{quantile="0.5"}' in text
    assert "serve_step_ms_count 2" in text
    json.dumps(snap)  # JSON-ready


def test_serve_stats_counters_are_registry_backed():
    from repro.serve import ServeStats

    stats = ServeStats()
    stats.prompt_tokens += 5
    stats.n_preemptions += 1
    assert stats.registry.counter("serve.prompt_tokens").value == 5.0
    assert stats.registry.counter("serve.preemptions").value == 1.0
    assert isinstance(stats.prompt_tokens, int)
    stats.reset()
    assert stats.prompt_tokens == 0 and stats.n_preemptions == 0
    assert stats.step_ms is stats.registry.series("serve.step_ms")


# ---------------------------------------------------------------------------
# tracer: ring bound, schema, validator


def test_tracer_ring_bound_never_exceeded():
    tr = Tracer(capacity=16)
    for i in range(200):
        tr.complete(f"e{i}", "t", float(i), 1.0)
    assert len(tr) == 16
    # oldest dropped first: the survivors are the newest 16
    assert tr.events[0][1] == "e184" and tr.events[-1][1] == "e199"
    counts = validate_chrome_trace(tr.to_chrome_trace())
    assert counts["spans"] == 16


def test_validator_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"nope": 1})
    bad_ph = [{"name": "x", "ph": "Z", "ts": 0, "pid": 1, "tid": 0}]
    with pytest.raises(ValueError, match="bad ph"):
        validate_chrome_trace(bad_ph)
    no_dur = [{"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 0}]
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(no_dur)
    orphan_end = [
        {"name": "x", "ph": "e", "ts": 0, "pid": 1, "tid": 0, "id": 3, "cat": "r"}
    ]
    with pytest.raises(ValueError, match="async end"):
        validate_chrome_trace(orphan_end)


def test_export_round_trips_through_json(tmp_path):
    tr = Tracer()
    tr.async_begin("request", 1, "request")
    tr.instant("mark", "sched")
    tr.async_end("request", 1, "request")
    path = tmp_path / "trace.json"
    tr.export(str(path))
    loaded = json.loads(path.read_text())
    counts = validate_chrome_trace(loaded)
    assert counts["async_spans"] == 1 and counts["instants"] == 1
    assert loaded["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# the hard off-switch: telemetry on == off, observation only


def test_off_switch_token_identity_and_no_events(served):
    cfg, _, _, eng = _model_state()
    spec = SpecConfig(k=2, drafter="ngram")
    off = Telemetry(enabled=False)
    on = Telemetry(enabled=True)

    out_off = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                        spec=spec, telemetry=off)
    assert len(off.tracer) == 0
    assert eng.stats.registry.ticks == 0  # disabled: no tick per step

    out_on = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                       spec=spec, telemetry=on)
    assert len(on.tracer) > 0
    assert eng.stats.registry.ticks > 0

    for a, b in zip(out_off.values(), out_on.values()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    window = eng.stats.registry.window_summary(8)
    assert window["admitted"] > 0
    assert window["step_cost_ms"] > 0
    assert 0.0 <= window["acceptance_rate"] <= 1.0
    assert window["pool_occupancy"] >= 0.0


def test_xla_annotations_noop_smoke(served):
    cfg, _, _, eng = _model_state()
    base = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0)
    annotated = Telemetry(enabled=True, xla_annotations=True)
    out = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                    telemetry=annotated)
    for a, b in zip(base.values(), out.values()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # disabled or un-annotated telemetry shares one no-op context
    off = Telemetry(enabled=False)
    assert off.annotate("x") is off.annotate("y")
    with Telemetry(enabled=True, xla_annotations=True).annotate("phase"):
        pass  # TraceAnnotation enters/exits cleanly outside any profile


# ---------------------------------------------------------------------------
# golden serving_summary: the registry refactor changed no schema


def test_golden_serving_summary_schema(served):
    cfg, _, _, eng = _model_state()
    golden = json.load(
        open(os.path.join(os.path.dirname(__file__), "golden_serving_summary.json"))
    )
    eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
              spec=SpecConfig(k=2, drafter="ngram"))
    s = eng.stats.serving_summary()
    assert sorted(s.keys()) == golden["keys"]
    assert sorted(s["speculative"].keys()) == golden["speculative_keys"]
    for key, want in golden["deterministic"].items():
        assert s[key] == want, key
    for key, want in golden["speculative_deterministic"].items():
        assert s["speculative"][key] == want, key
    # latency fields are machine-dependent: type-checked only
    for key in golden["keys"]:
        if key.startswith(("p50_", "p99_")):
            assert s[key] is None or isinstance(s[key], float), key


# ---------------------------------------------------------------------------
# trace well-formedness over random open-loop traffic


def _span_nesting_ok(spans):
    """X-events on one lane either nest or are disjoint: sweeping by
    (ts, -dur), every span starts at-or-after its enclosing span's
    start and must end by the enclosing end."""
    stack = []
    for ts, dur in sorted(spans, key=lambda s: (s[0], -s[1])):
        end = ts + dur
        while stack and ts >= stack[-1] - 1e-6:
            stack.pop()
        if stack and end > stack[-1] + 1e-6:
            return False
        stack.append(end)
    return True


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_requests=st.integers(3, 6),
    gap_ms=st.sampled_from([0.0, 5.0]),
)
def test_trace_wellformed_random_traffic(seed, n_requests, gap_ms):
    cfg, _, _, eng = _model_state()
    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, size=int(rng.choice([8, 12]))).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 6)),
            arrival_time=gap_ms * 1e-3 * i,
            priority=int(rng.integers(0, 2)),
        )
        for i in range(n_requests)
    ]
    tel = Telemetry(enabled=True)
    eng.serve(list(reqs), max_batch=2, seed=seed,
              spec=SpecConfig(k=2, drafter="ngram"), telemetry=tel)

    events = tel.tracer.events
    rids = {r.rid for r in reqs}
    begins = [e for e in events if e[0] == "b" and e[1] == "request"]
    ends = [e for e in events if e[0] == "e" and e[1] == "request"]
    assert {e[6] for e in begins} == rids  # every submission opened a span
    # exactly one terminal event per admitted request
    assert sorted(e[6] for e in ends) == sorted(rids)

    # step/phase spans nest on the scheduler lane
    spans = [(e[3], e[4]) for e in events if e[0] == "X" and e[5] == TID_STEP]
    assert spans, "no step spans recorded"
    assert _span_nesting_ok(spans)

    counts = validate_chrome_trace(tel.tracer.to_chrome_trace())
    assert counts["async_spans"] == len(rids)

    # tiny-capacity rerun: the ring bound holds under the same load
    # (async validation is skipped — eviction may drop a span's begin)
    tiny = Telemetry(enabled=True, capacity=24)
    eng.serve(
        [Request(prompt=np.asarray(r.prompt), max_new_tokens=r.max_new_tokens,
                 arrival_time=r.arrival_time, priority=r.priority) for r in reqs],
        max_batch=2, seed=seed, spec=SpecConfig(k=2, drafter="ngram"),
        telemetry=tiny,
    )
    assert len(tiny.tracer) <= 24


def test_preemption_events_in_trace(served):
    """Block pressure → preempt + resume instants and a terminal event
    for every request, preempted ones included."""
    _, m, params, _ = _model_state()
    eng = ServingEngine(m, params, max_seq=128, kv_layout="paged",
                        max_batch=2, block_size=8, num_blocks=10)
    low = [
        Request(prompt=np.arange(20, dtype=np.int32) + i, max_new_tokens=10,
                arrival_time=0.0, priority=0)
        for i in range(2)
    ]
    high = [Request(prompt=np.arange(9, dtype=np.int32), max_new_tokens=4,
                    arrival_time=0.02, priority=5)]
    tel = Telemetry(enabled=True)
    eng.serve(low + high, telemetry=tel)
    assert eng.stats.n_preemptions > 0, "pressure scenario did not evict"
    names = [e[1] for e in tel.tracer.events]
    assert "preempt" in names and "resume" in names
    ends = [e for e in tel.tracer.events if e[0] == "e"]
    assert sorted(e[6] for e in ends) == sorted(r.rid for r in low + high)
    validate_chrome_trace(tel.tracer.to_chrome_trace())


# ---------------------------------------------------------------------------
# adviser audit trail


def test_advisor_decisions_annotated(monkeypatch):
    import repro.serve.telemetry as telemetry_mod
    from repro.core.tools import (
        KernelAdvisorTool,
        KernelMeasurement,
        SpecMeasurement,
        SpeculationAdvisorTool,
    )

    tel = Telemetry(enabled=True)
    monkeypatch.setattr(telemetry_mod, "GLOBAL", tel)

    k, gain, _ = SpeculationAdvisorTool().choose(
        SpecMeasurement(0.05, {0: 2.0, 8: 3.0}, 0.7)
    )
    backend, _, _ = KernelAdvisorTool().choose(
        KernelMeasurement.make("llama", "paged", 2, {"reference": 2.0, "kernel": 1.0})
    )
    by_name = {e[1]: e for e in tel.tracer.events}
    spec_ev = by_name["speculation-decision"]
    assert spec_ev[5] == TID_ADVISER
    assert spec_ev[7]["k"] == k
    # priced inputs ride along with the decision
    assert spec_ev[7]["acceptance_rate"] == pytest.approx(0.7)
    assert spec_ev[7]["draft_ms_per_token"] == pytest.approx(0.05)
    kern_ev = by_name["kernel-backend-decision"]
    assert kern_ev[7]["backend"] == backend == "kernel"
    assert kern_ev[7]["step_ms"]["reference"] == pytest.approx(2.0)
    assert telemetry_mod.global_registry().counter("adviser.decisions").value >= 2


def test_pipeline_stage_spans(monkeypatch):
    import jax.numpy as jnp

    import repro.serve.telemetry as telemetry_mod
    from repro.core import Aira, Region, Workload
    from repro.core.overlap_model import CPU_HW

    tel = Telemetry(enabled=True)
    monkeypatch.setattr(telemetry_mod, "GLOBAL", tel)

    region = Region(
        "audit", lambda x: 2.0 * x + 1.0, jnp.arange(4096, dtype=jnp.float32),
        task_flops=100.0, task_bytes=512.0, task_chain=16,
    )
    Aira(hw=CPU_HW).advise(Workload("w", lambda: None, [region]))
    stage_events = [
        e for e in tel.tracer.events
        if e[0] == "X" and e[5] == TID_ADVISER and e[1].startswith("tool:")
    ]
    stages = [e[1] for e in stage_events]
    assert "tool:profile" in stages and "tool:simulate" in stages
    for e in stage_events:
        assert e[7]["region"] == "audit"
        assert e[7]["verdict"] in ("pass", "reject")
    # disabled recorder: the same pipeline leaves no events
    silent = Telemetry(enabled=False)
    monkeypatch.setattr(telemetry_mod, "GLOBAL", silent)
    region2 = Region(
        "silent", lambda x: 2.0 * x + 1.0, jnp.arange(4096, dtype=jnp.float32),
        task_flops=100.0, task_bytes=512.0, task_chain=16,
    )
    Aira(hw=CPU_HW).advise(Workload("w2", lambda: None, [region2]))
    assert len(silent.tracer) == 0


def test_backend_resolution_annotated(monkeypatch):
    import repro.kernels.ops as ops
    import repro.serve.telemetry as telemetry_mod

    tel = Telemetry(enabled=True)
    monkeypatch.setattr(telemetry_mod, "GLOBAL", tel)
    monkeypatch.setattr(ops, "_DEFAULT_MODE", None)  # force a fresh resolution
    ops.default_kernel_mode()
    names = [e[1] for e in tel.tracer.events]
    assert "kernel-mode-resolved" in names
    assert telemetry_mod.global_registry().counter("backend.resolutions").value >= 1
