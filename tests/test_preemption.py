"""Priority scheduling + block-pressure preemption: preempt→resume is
token-identical to an uninterrupted run (both layouts, ± speculation),
priority order is respected, nobody starves under random mixed-priority
load, the paged trie re-registration makes resumption suffix-only, and
the new ServeStats counters (preemptions, recomputed tokens, queue-wait
split, rejected submissions) account for all of it."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_config
from repro.models import Model
from repro.serve import Request, ServingEngine, SpecConfig


_STATE: dict = {}


def _model_state():
    """Lazy module singleton (not a fixture: the hypothesis stub calls
    property tests with drawn args only, so they can't take fixtures)."""
    if not _STATE:
        cfg = get_config("smollm-135m").reduced()
        m = Model(cfg)
        params, _ = m.init(jax.random.key(0))
        _STATE["v"] = (cfg, m, params)
    return _STATE["v"]


@pytest.fixture(scope="module")
def served():
    return _model_state()


def _pressure_workload():
    """Two long low-priority requests admitted first, then a
    high-priority arrival that needs their row: preemption by design."""
    low = [
        Request(prompt=np.arange(20, dtype=np.int32) + i, max_new_tokens=10,
                arrival_time=0.0, priority=0)
        for i in range(2)
    ]
    high = [Request(prompt=np.arange(9, dtype=np.int32), max_new_tokens=4,
                    arrival_time=0.02, priority=5)]
    return low + high


def _engines(m, params, layout, tight):
    """(pressured, roomy) engines: same model, the first sized so the
    high-priority arrival must evict, the second so nothing ever waits."""
    kw = dict(block_size=8, num_blocks=10) if tight else dict(block_size=8)
    pressured = ServingEngine(
        m, params, max_seq=128, kv_layout=layout, max_batch=2, **kw
    )
    roomy = ServingEngine(
        m, params, max_seq=128, kv_layout=layout, max_batch=4, block_size=8
    )
    return pressured, roomy


# ---------------------------------------------------------------------------
# preempt → resume token identity


@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_preempt_resume_token_identity(served, layout):
    """An evicted-and-resumed request decodes bitwise what it would
    have decoded uninterrupted — preemption moves work, never tokens."""
    _, m, params = served
    pressured, roomy = _engines(m, params, layout, tight=layout == "paged")
    p_reqs = _pressure_workload()
    p_out = pressured.serve(p_reqs)
    assert pressured.stats.n_preemptions > 0, "pressure scenario did not evict"
    assert pressured.stats.recomputed_tokens > 0
    assert all(r.finished for r in p_reqs)
    assert any(r.preemptions > 0 for r in p_reqs)

    r_reqs = _pressure_workload()
    r_out = roomy.serve(r_reqs)
    assert roomy.stats.n_preemptions == 0
    for a, b in zip(p_reqs, r_reqs):
        np.testing.assert_array_equal(p_out[a.rid], r_out[b.rid])


@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_preempt_resume_with_speculation(served, layout):
    """Preemption composes with speculative decoding: the saved sample
    key and draft catch-up make the resumed stream bitwise the plain
    uninterrupted greedy one."""
    _, m, params = served
    spec = SpecConfig(k=4, drafter="ngram")
    pressured, roomy = _engines(m, params, layout, tight=layout == "paged")
    p_reqs = _pressure_workload()
    p_out = pressured.serve(p_reqs, spec=spec)
    assert pressured.stats.n_preemptions > 0
    r_reqs = _pressure_workload()
    r_out = roomy.serve(r_reqs, spec=SpecConfig(k=0))
    for a, b in zip(p_reqs, r_reqs):
        np.testing.assert_array_equal(p_out[a.rid], r_out[b.rid])


def test_preempt_resume_chunked(served):
    """Preemption under chunked prefill: the resume recompute walks the
    chunk path and still lands on the identical stream."""
    _, m, params = served
    pressured, roomy = _engines(m, params, "paged", tight=True)
    p_reqs = _pressure_workload()
    p_out = pressured.serve(p_reqs, chunk_size=8)
    assert pressured.stats.n_preemptions > 0
    r_reqs = _pressure_workload()
    r_out = roomy.serve(r_reqs, chunk_size=0)
    for a, b in zip(p_reqs, r_reqs):
        np.testing.assert_array_equal(p_out[a.rid], r_out[b.rid])


# ---------------------------------------------------------------------------
# priority order and starvation


def test_priority_order_first_service(served):
    """With one row and simultaneous arrivals, first admission follows
    (-priority, arrival, rid) strictly."""
    _, m, params = served
    eng = ServingEngine(m, params, max_seq=64, kv_layout="slot", max_batch=1)
    reqs = [
        Request(prompt=np.arange(4, dtype=np.int32) + i, max_new_tokens=2,
                arrival_time=0.0, priority=p)
        for i, p in enumerate([0, 3, 1, 3])
    ]
    eng.serve(reqs)
    order = sorted(reqs, key=lambda r: r.t_first_admit)
    assert [r.rid for r in order] == [
        r.rid for r in sorted(reqs, key=lambda r: (-r.priority, r.arrival_time, r.rid))
    ]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_no_starvation_random_mixed_priorities(seed):
    """Property: random arrivals, lengths, budgets, and priorities on a
    tight paged pool — every admitted request finishes with its full
    budget, and the pool's invariants hold afterwards. Strict priority
    cannot starve: arrivals are finite and every preemption strictly
    raises the running set's priority."""
    _, m, params = _model_state()
    rng = np.random.default_rng(seed)
    eng = ServingEngine(
        m, params, max_seq=96, kv_layout="paged", max_batch=2,
        block_size=8, num_blocks=12,
    )
    n = int(rng.integers(3, 7))
    reqs = [
        Request(
            prompt=rng.integers(0, 100, size=(int(rng.integers(2, 24)),)).astype(np.int32),
            max_new_tokens=int(rng.integers(1, 8)),
            arrival_time=float(rng.uniform(0, 0.05)),
            priority=int(rng.integers(0, 3)),
        )
        for _ in range(n)
    ]
    sched = eng.scheduler(2)
    out = sched.run(reqs)
    assert all(r.finished for r in reqs)
    for r in reqs:
        assert len(out[r.rid]) == r.max_new_tokens  # no eos: full budget
    sched.kv.check_invariants()


# ---------------------------------------------------------------------------
# paged trie re-registration: resume is suffix-only recompute


def test_paged_preempt_reregisters_committed_blocks(served):
    """Eviction parks the victim's committed full blocks in the trie, so
    its resume prefix-matches its own history: the recompute is the
    uncommitted suffix, not the whole prompt."""
    _, m, params = served
    eng, _ = _engines(m, params, "paged", tight=True)
    reqs = _pressure_workload()
    eng.serve(reqs)
    assert eng.stats.n_preemptions > 0
    victim = next(r for r in reqs if r.preemptions > 0)
    # committed history at eviction ≥ the prompt's full blocks; the
    # resume recompute must be smaller than recomputing from scratch
    assert 0 < eng.stats.recomputed_tokens < (
        eng.stats.n_preemptions * (len(victim.prompt) + victim.max_new_tokens)
    )


# ---------------------------------------------------------------------------
# stats accounting


def test_preemption_stats_and_queue_wait_split(served):
    _, m, params = served
    eng, _ = _engines(m, params, "paged", tight=True)
    reqs = _pressure_workload()
    eng.serve(reqs)
    s = eng.stats.serving_summary()
    assert s["preemptions"] == eng.stats.n_preemptions > 0
    assert s["recomputed_tokens"] == eng.stats.recomputed_tokens > 0
    assert s["rejected_submissions"] == 0
    for key in ("p50_queue_wait_ms", "p99_queue_wait_ms",
                "p50_service_ttft_ms", "p99_service_ttft_ms"):
        assert s[key] is not None
    for r in reqs:  # queue wait + service = TTFT, each leg nonnegative
        assert 0 <= r.queue_wait_ms <= r.ttft_ms + 1e-9
        assert abs(r.queue_wait_ms + r.service_ttft_ms - r.ttft_ms) < 1e-6


def test_rejected_submission_counted(served):
    _, m, params = served
    eng = ServingEngine(m, params, max_seq=16, kv_layout="slot", max_batch=1)
    sched = eng.scheduler(1)
    with pytest.raises(ValueError, match="max_seq"):
        sched.submit(Request(prompt=np.arange(20, dtype=np.int32), max_new_tokens=4))
    assert eng.stats.rejected_submissions == 1
    sched.submit(Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=4))
    assert eng.stats.rejected_submissions == 1
