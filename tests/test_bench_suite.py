"""The 10 paper benchmarks: restructured == serial, traces clean,
granularity bands shaped like Figs. 1–2."""
import jax
import numpy as np
import pytest

from repro.bench_suite import BENCHMARKS
from repro.core.deps import check_conflicts


@pytest.mark.parametrize("name", list(BENCHMARKS), ids=list(BENCHMARKS))
def test_restructured_matches_serial(name):
    b = BENCHMARKS[name]
    data = b.build()
    want = np.asarray(b.serial_value(data), np.float32)
    got = np.asarray(b.parallel_value(data, granularity=8), np.float32)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize(
    "name", [n for n, b in BENCHMARKS.items() if b.trace is not None]
)
def test_traces_conflict_free(name):
    b = BENCHMARKS[name]
    data = b.build()
    conflict, why = check_conflicts(b.trace(data), n_tasks=2)
    assert not conflict, why


def test_fig1_band_structure():
    """PFL (compute-bound): small-n negative everywhere, SMT less bad
    than SMP; positive but small SMT gain at 1000 (paper: +5.1%)."""
    from benchmarks.fig12_granularity import sweep
    from repro.bench_suite import pfl

    rows = {r["n"]: r for r in sweep(pfl.microtask())}
    assert rows[10]["relic_smt"] < 0 and rows[10]["relic_smp"] < 0
    assert rows[10]["relic_smt"] > rows[10]["relic_smp"]
    assert 0.0 < rows[1000]["relic_smt"] < 0.12
    assert rows[1000]["relic_smt"] > rows[1000]["openmp_smt"]


def test_fig2_band_structure():
    """CC (memory-bound): a fine-granularity band where Relic-SMT is
    positive while OpenMP degrades; SMP wins at coarse granularity."""
    from benchmarks.fig12_granularity import sweep
    from repro.bench_suite import cc

    rows = {r["n"]: r for r in sweep(cc.microtask())}
    assert rows[25]["relic_smt"] > 0 > rows[25]["openmp_smp"]
    assert rows[25]["relic_smt"] > rows[25]["relic_smp"]
    assert rows[16000]["relic_smp"] > rows[16000]["relic_smt"]


def test_lob_books_disjoint_across_symbols():
    b = BENCHMARKS["LOB"]
    data = b.build()
    tr = b.trace(data)
    w0 = set(np.asarray(tr.writes[0]).tolist())
    w1 = set(np.asarray(tr.writes[1]).tolist())
    assert not (w0 & w1)
