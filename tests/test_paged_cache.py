"""Paged KV cache: BlockAllocator/PrefixCache property tests, paged vs
slotted vs fixed-batch differential equivalence under greedy decode,
prefix-cache semantics (hit length, token identity, eviction restores
the cold path), and block-granular admission control."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_config
from repro.models import Model
from repro.serve import (
    BlockAllocator,
    PagedKVCache,
    PrefixCache,
    Request,
    ServingEngine,
)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (6, 16), 0, cfg.vocab_size)
    return cfg, m, params, prompts


# ---------------------------------------------------------------------------
# BlockAllocator property tests


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), num_blocks=st.integers(1, 12))
def test_block_allocator_random_ops_conserve_refcounts(seed, num_blocks):
    """Under random alloc/share/free(park)/evict sequences: refcounts
    equal the references the driver holds, {free, parked, live} stay a
    partition (no block both free and referenced), and LRU eviction
    only ever takes refcount-0 blocks."""
    rng = random.Random(seed)
    alloc = BlockAllocator(num_blocks)
    held: list[int] = []  # our referents, with multiplicity
    for _ in range(250):
        ops = []
        if alloc.n_available:
            ops += ["alloc"] * 2
        if held:
            ops += ["share", "free", "park"]
        if alloc.n_parked:
            ops.append("evict")
        op = rng.choice(ops)
        if op == "alloc":
            held.append(alloc.alloc())
        elif op == "share":
            b = rng.choice(held)
            alloc.share(b)
            held.append(b)
        elif op in ("free", "park"):
            b = held.pop(rng.randrange(len(held)))
            alloc.free(b, park=op == "park")
        elif op == "evict":
            parked = [b for b in range(num_blocks) if alloc.is_parked(b)]
            alloc.evict(rng.choice(parked))
        alloc.check_invariants()
        counts = [held.count(b) for b in range(num_blocks)]
        assert counts == alloc.refcount, "refcounts not conserved"
    # evicting a referenced block is impossible
    if not held:
        held.append(alloc.alloc())
    with pytest.raises(RuntimeError, match="refcount"):
        alloc.evict(held[0])
    # and so are double free / sharing a free block
    b = held.pop()
    alloc.free(b)
    if b not in held:
        with pytest.raises(RuntimeError, match="double free"):
            alloc.free(b)
        with pytest.raises(RuntimeError, match="free block"):
            alloc.share(b)


def test_block_allocator_lru_eviction_order_and_exhaustion():
    alloc = BlockAllocator(3)
    a, b, c = alloc.alloc(), alloc.alloc(), alloc.alloc()
    with pytest.raises(RuntimeError, match="no free KV block"):
        alloc.alloc()
    alloc.free(b, park=True)  # parked first → LRU victim
    alloc.free(a, park=True)
    assert alloc.alloc() == b  # evicts least-recently-parked, reuses it
    alloc.share(a)  # reactivate the parked survivor
    assert alloc.refcount[a] == 1 and not alloc.is_parked(a)
    alloc.check_invariants()


def test_eviction_under_pressure_takes_leaves_not_chain_roots(served):
    """Reclaiming one block under memory pressure evicts the oldest
    parked *leaf*, so a cached prefix chain shrinks from its divergence
    tail inward instead of being cascaded away root-first."""
    _, m, _, _ = served
    kv = PagedKVCache(m, max_batch=2, max_seq=16, block_size=4, num_blocks=5)
    row, _ = kv.try_admit(0, tuple(range(16)), 1)
    kv.free_row(row)  # 4 prompt blocks registered, parked; 1 reserve freed
    assert kv.allocator.n_parked == 4
    # a new unrelated request needs 2 fresh blocks → evicts 1-2 leaves
    row2, hits = kv.try_admit(1, tuple(range(100, 105)), 3)
    assert hits == []
    kv.check_invariants()
    # the surviving chain still matches from the root
    survivors = kv.lookup(tuple(range(16)))
    assert len(survivors) >= 1, "root of the cached chain was evicted"
    assert survivors == [b for b in survivors if kv.prefix.registered(b)]


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000), block_size=st.integers(1, 4))
def test_prefix_trie_random_ops_match_reference(seed, block_size):
    """Under random insert/match/cascade-drop sequences the trie agrees
    with a brute-force reference (block id → its full key-chain path):
    match returns exactly the longest registered chain prefix (capped so
    one suffix token remains), insert registers only the novel tail of
    a chain, and dropping a block drops precisely its subtree."""
    rng = random.Random(seed)
    pc = PrefixCache(block_size)
    chains: dict[int, tuple] = {}  # block → key-chain path (ref model)
    next_block = 0

    def ref_match(tokens):
        keys = [
            tuple(tokens[j * block_size : (j + 1) * block_size])
            for j in range((len(tokens) - 1) // block_size)
        ]
        out = []
        by_path = {path: b for b, path in chains.items()}
        for j in range(len(keys)):
            b = by_path.get(tuple(keys[: j + 1]))
            if b is None:
                break
            out.append(b)
        return out

    for _ in range(120):
        op = rng.choice(["insert", "match", "match", "drop"] if chains else ["insert", "match"])
        tokens = tuple(rng.randrange(4) for _ in range(rng.randint(0, 4 * block_size)))
        if op == "insert":
            n_blocks = rng.randint(0, len(tokens) // block_size)
            keys = [
                tuple(tokens[j * block_size : (j + 1) * block_size])
                for j in range(n_blocks)
            ]
            by_path = {path: b for b, path in chains.items()}
            ids = []
            for j in range(n_blocks):
                path = tuple(keys[: j + 1])
                if path in by_path:
                    ids.append(by_path[path])  # existing chain keeps its block
                else:
                    ids.append(next_block)
                    chains[next_block] = path
                    by_path[path] = next_block
                    next_block += 1
            pc.insert(tokens, ids)
        elif op == "match":
            assert pc.match(tokens) == ref_match(tokens)
        else:
            b = rng.choice(sorted(chains))
            bpath = chains[b]
            dropped = pc.drop_block(b)
            want = {d for d, p in chains.items() if p[: len(bpath)] == bpath and d != b}
            assert set(dropped) == want, "cascade != subtree"
            for d in list(chains):
                if chains[d][: len(bpath)] == bpath:
                    del chains[d]
        assert pc.n_blocks == len(chains)
        for b in chains:
            assert pc.registered(b)
            has_children = any(
                p[: len(chains[b])] == chains[b] and d != b for d, p in chains.items()
            )
            assert pc.is_leaf(b) == (not has_children)


def test_prefix_trie_match_insert_drop_cascade():
    """match walks full-block chains only (capped so one suffix token
    remains); dropping an interior block drops its whole subtree."""
    pc = PrefixCache(4)
    t = tuple(range(12))
    pc.insert(t, [10, 11, 12])
    assert pc.match(t + (99,)) == [10, 11, 12]
    assert pc.match(t) == [10, 11]  # cap: (12-1)//4 = 2 blocks
    assert pc.match((0, 1, 2, 3, 7, 7, 7, 7, 9)) == [10]  # diverges at block 1
    assert pc.match((5, 5, 5, 5, 5)) == []
    # dropping the middle block orphans — and drops — its subtree
    assert pc.drop_block(11) == [12]
    assert pc.match(t + (99,)) == [10]
    assert not pc.registered(11) and not pc.registered(12)
    assert pc.drop_block(999) == []  # unknown block: no-op


# ---------------------------------------------------------------------------
# paged pool invariants


def test_paged_cache_admission_lifecycle_invariants(served):
    """Random admit/decode-advance/finish traffic against PagedKVCache
    keeps rows, tables, refcounts, and reservations consistent."""
    _, m, _, _ = served
    kv = PagedKVCache(m, max_batch=3, max_seq=16, block_size=4, num_blocks=9)
    rng = random.Random(0)
    live: dict[int, int] = {}  # row → remaining budget
    rid = 0
    for _ in range(200):
        if rng.random() < 0.4:
            S, budget = rng.randint(1, 8), rng.randint(1, 4)
            tokens = tuple(rng.randrange(7) for _ in range(S))
            got = kv.try_admit(rid, tokens, budget)
            if got is not None:
                row, _hits = got
                assert kv.owner(row) == rid
                live[row] = budget
                rid += 1
        elif live:
            row = rng.choice(sorted(live))
            if rng.random() < 0.5 and live[row] > 0:
                kv.ensure_tail(row)  # decode writes one token
                kv.advance(row)
                live[row] -= 1
            else:
                kv.free_row(row)
                del live[row]
        kv.check_invariants()
    if not live:
        row, _ = kv.try_admit(rid, (1, 2), 1)
    else:
        row = next(iter(live))
    kv.free_row(row)
    with pytest.raises(RuntimeError, match="double free"):
        kv.free_row(row)


def test_kernel_inputs_hoists_invariant_device_views(served):
    """``kernel_inputs()`` re-uploads only what actually changed: across
    pure decode steps (advance only) the device block-table view is the
    SAME object — zero per-step host allocations beyond the lengths
    vector — and table mutations (admit / lazy tail claim / truncate /
    free) each invalidate exactly the table view."""
    _, m, _, _ = served
    kv = PagedKVCache(m, max_batch=2, max_seq=16, block_size=4, num_blocks=8)
    row, _ = kv.try_admit(0, (1, 2, 3), budget=8)
    _, t0, l0 = kv.kernel_inputs()
    # same state → identical objects, no re-upload at all
    _, t1, l1 = kv.kernel_inputs()
    assert t1 is t0 and l1 is l0
    # steady decode inside a block: lengths refresh, tables do not
    kv.ensure_tail(row)  # block 0 already covers position 3
    kv.advance(row)
    _, t2, l2 = kv.kernel_inputs()
    assert t2 is t0, "pure advance must not re-upload the block tables"
    assert l2 is not l0
    np.testing.assert_array_equal(np.asarray(l2), kv.cache_len)
    # crossing a block boundary claims a tail block → tables invalidate
    kv.advance(row)  # len 5: next write position enters block 1
    kv.ensure_tail(row)
    _, t3, _ = kv.kernel_inputs()
    assert t3 is not t0
    np.testing.assert_array_equal(np.asarray(t3), kv.block_tables)
    # speculative rewind releases the claimed tail block → tables invalidate
    kv.advance_n(row, 3)
    kv.truncate_row(row, 4)
    _, t4, l4 = kv.kernel_inputs()
    assert t4 is not t3
    np.testing.assert_array_equal(np.asarray(t4), kv.block_tables)
    np.testing.assert_array_equal(np.asarray(l4), kv.cache_len)
    # retire → tables and lengths both invalidate
    kv.free_row(row)
    _, t5, l5 = kv.kernel_inputs()
    assert t5 is not t4 and l5 is not l4
    np.testing.assert_array_equal(np.asarray(t5), kv.block_tables)


_MUT = {}


def _mutation_fixture():
    """Module memo (the hypothesis stub's ``given`` wrapper takes no
    pytest fixtures): one reduced model plus one batch=1 dense prefill
    reused as the ``write_prefill`` payload."""
    if not _MUT:
        cfg = get_config("smollm-135m").reduced()
        m = Model(cfg)
        params, _ = m.init(jax.random.key(0))
        prompt = jnp.arange(6, dtype=jnp.int32)[None]
        _, dense = jax.jit(lambda p, t: m.prefill(p, t, 16))(params, prompt)
        _MUT["m"], _MUT["dense"] = m, dense
    return _MUT["m"], _MUT["dense"]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_any_mutation_refreshes_kernel_inputs(seed):
    """Version-counter property: after ANY public mutation — admit,
    prefill install, lazy tail claim, decode/verify advance, rewind,
    preempt, retire — the next ``kernel_inputs()`` device views equal
    the host tables/lengths exactly, and an un-mutated re-read returns
    the identical cached objects. A missed ``_tables_version`` /
    ``_len_version`` bump anywhere in the mutation surface fails this
    under some op sequence."""
    m, dense = _mutation_fixture()
    rng = random.Random(seed)
    kv = PagedKVCache(m, max_batch=3, max_seq=16, block_size=4, num_blocks=12)
    rid = 0
    live: dict[int, tuple] = {}  # row -> (prompt tokens, length limit)

    def check():
        _, t, l = kv.kernel_inputs()
        np.testing.assert_array_equal(np.asarray(t), kv.block_tables)
        np.testing.assert_array_equal(np.asarray(l), kv.cache_len)
        _, t2, l2 = kv.kernel_inputs()  # no mutation in between
        assert t2 is t and l2 is l, "un-mutated re-read must hit the cache"

    for _ in range(120):
        ops = []
        if kv.n_free:
            ops += ["admit"]
        if live:
            ops += ["decode", "verify", "prefill", "free", "preempt"]
        op = rng.choice(ops)
        if op == "admit":
            plen = rng.randint(1, 6)
            toks = tuple(rng.randrange(50) for _ in range(plen))
            budget = rng.randint(1, 6)
            r = kv.try_admit(rid, toks, budget=budget)
            rid += 1
            if r is not None:
                live[r[0]] = (toks, plen + budget)
        elif op == "decode":
            row = rng.choice(sorted(live))
            if int(kv.cache_len[row]) < live[row][1]:
                kv.ensure_tail(row)
                check()
                kv.advance(row)
        elif op == "verify":
            # verify-style burst: claim + advance n, rewind a rejected tail
            row = rng.choice(sorted(live))
            room = live[row][1] - int(kv.cache_len[row])
            if room > 0:
                n = rng.randint(1, min(3, room))
                kv.ensure_tail_n(row, n)
                check()
                kv.advance_n(row, n)
                check()
                k = rng.randint(0, n)
                if k:
                    kv.truncate_row(row, k)
        elif op == "prefill":
            row = rng.choice(sorted(live))
            kv.write_prefill(row, dense)
        elif op == "free":
            row = rng.choice(sorted(live))
            kv.free_row(row)
            del live[row]
        elif op == "preempt":
            row = rng.choice(sorted(live))
            kv.preempt_row(row, tokens=live[row][0] if rng.random() < 0.5 else None)
            del live[row]
        check()
        kv.check_invariants()


def test_paged_cache_rejects_non_attention_family():
    cfg = get_config("mamba2-370m").reduced()
    m = Model(cfg)
    with pytest.raises(ValueError, match="attention family"):
        PagedKVCache(m, max_batch=2, max_seq=16, block_size=4)


def test_prefill_with_prefix_rejects_token_divergent_families():
    """The model-level guard mirrors PREFIX_FAMILIES: MoE capacity
    routing (and VLM patch rows) would make suffix prefill diverge from
    the cold run, so a direct call must fail loudly, like int8-KV."""
    moe = Model(get_config("granite-moe-1b-a400m").reduced())
    with pytest.raises(ValueError, match="token-identical"):
        moe.prefill_with_prefix(None, None, None, None, 16)


# ---------------------------------------------------------------------------
# differential: paged == slotted == fixed-batch generate (greedy)


def _trace(prompts, lens, budgets, eos=None, eos_req=None):
    return [
        Request(
            prompt=np.asarray(prompts[i, : lens[i]]),
            max_new_tokens=budgets[i],
            arrival_time=0.01 * i,
            eos_id=eos if i == eos_req else None,
        )
        for i in range(len(lens))
    ]


@pytest.mark.parametrize("seed", [0, 3])
def test_differential_paged_vs_slotted_vs_generate(served, seed):
    """Randomized open-loop trace — staggered arrivals, divergent prompt
    lengths and budgets, one EOS early finish — decodes token-identical
    through the paged engine, the slotted engine, and the per-request
    fixed-batch ``generate()`` baseline."""
    _, m, params, prompts = served
    rng = np.random.default_rng(seed)
    n = 5
    lens = rng.integers(3, 16, size=n)
    budgets = rng.integers(2, 7, size=n)

    # per-request fixed-batch baselines (and an EOS from request 0's
    # stream so one request finishes early through a real token match)
    eng = ServingEngine(m, params, max_seq=64)
    bases = [
        np.asarray(eng.generate(prompts[i : i + 1, : lens[i]], n_steps=int(budgets[i]))[0])
        for i in range(n)
    ]
    eos = int(bases[0][min(1, budgets[0] - 1)])
    cut = int(np.argmax(bases[0] == eos))  # first occurrence
    expected = [b if i != 0 else b[: cut + 1] for i, b in enumerate(bases)]

    slotted = ServingEngine(m, params, max_seq=64)
    out_slot = slotted.serve(_trace(prompts, lens, budgets, eos, 0), max_batch=3)
    paged = ServingEngine(m, params, max_seq=64, kv_layout="paged", block_size=4)
    reqs = _trace(prompts, lens, budgets, eos, 0)
    sched = paged.scheduler(3)
    out_paged = sched.run(reqs)
    sched.kv.check_invariants()

    # rids increment in creation order, so sorting aligns with expected
    for i, (_rid, out) in enumerate(sorted(out_slot.items())):
        np.testing.assert_array_equal(out, expected[i])
    for i, req in enumerate(reqs):
        np.testing.assert_array_equal(out_paged[req.rid], expected[i])
        assert req.finished and req.ttft_ms is not None


def test_paged_matches_slotted_with_int8_kv(served):
    """The paged gather/scatter treats every seq-indexed leaf uniformly,
    so the int8 KV cache (values + scales) pages bit-identically; with
    distinct prompts the prefix cache (now live for int8 too) never
    hits, so reuse cannot perturb this differential."""
    import dataclasses

    cfg, _, _, prompts = served
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    m = Model(qcfg)
    params, _ = m.init(jax.random.key(0))
    base = ServingEngine(m, params, max_seq=32).generate(prompts[:2, :8], n_steps=4)
    eng = ServingEngine(m, params, max_seq=32, kv_layout="paged", block_size=8)
    reqs = [
        Request(prompt=prompts[i, :8], max_new_tokens=4, arrival_time=0.01 * i)
        for i in range(2)
    ]
    sched = eng.scheduler(2)
    out = sched.run(reqs)
    assert sched.kv.prefix is not None  # int8 KV participates in reuse now
    assert all(r.prefix_hit == 0 for r in reqs)  # …but distinct prompts miss
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(out[r.rid], np.asarray(base[i]))


def test_int8_prefix_reuse_matches_cold_prefill(served):
    """Shared-prefix reuse on the int8 cache: hit blocks dequantize into
    the suffix path (a ≤1/254 relative perturbation vs the fp rows the
    cold run attended — approximate by design, see DESIGN.md §3.1) and
    the refill requantizes idempotently. On this config the greedy
    tokens match a cold, reuse-off run of the same int8 engine."""
    import dataclasses

    cfg, _, _, prompts = served
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    m = Model(qcfg)
    params, _ = m.init(jax.random.key(0))
    p1 = np.asarray(prompts[0])  # 16 tokens
    p2 = np.concatenate([p1[:12], np.asarray(prompts[1, :4])])  # 75% shared

    cold = ServingEngine(
        m, params, max_seq=64, kv_layout="paged", block_size=4, prefix_cache=False
    )
    r = Request(prompt=p1, max_new_tokens=4)
    cold1 = cold.serve([r], max_batch=2)[r.rid]
    r = Request(prompt=p2, max_new_tokens=4)
    cold2 = cold.serve([r], max_batch=2)[r.rid]

    eng = ServingEngine(m, params, max_seq=64, kv_layout="paged", block_size=4)
    sched = eng.scheduler(2)
    r1 = Request(prompt=p1, max_new_tokens=4)
    r2 = Request(prompt=p2, max_new_tokens=4)
    out = sched.run([r1, r2])
    sched.kv.check_invariants()
    assert r1.prefix_hit == 0 and r2.prefix_hit == 12
    np.testing.assert_array_equal(out[r1.rid], cold1)
    np.testing.assert_array_equal(out[r2.rid], cold2)


def test_paged_eviction_under_block_pressure_stays_correct(served):
    """A pool with barely enough blocks forces LRU eviction of cached
    prompt blocks while serving; outputs still match the baselines and
    admission never deadlocks."""
    _, m, params, prompts = served
    lens, budgets = (12, 8, 14), (4, 6, 3)
    eng = ServingEngine(m, params, max_seq=32)
    bases = [
        np.asarray(eng.generate(prompts[i : i + 1, : lens[i]], n_steps=budgets[i])[0])
        for i in range(3)
    ]
    paged = ServingEngine(
        m, params, max_seq=32, kv_layout="paged", block_size=4, num_blocks=6
    )
    reqs = _trace(prompts, lens, budgets)
    sched = paged.scheduler(2)
    out = sched.run(reqs)
    sched.kv.check_invariants()
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(out[r.rid], bases[i])


# ---------------------------------------------------------------------------
# prefix-cache semantics


def test_shared_prefix_hit_length_tokens_and_eviction(served):
    """Two requests sharing a 75% prefix: the second reports the expected
    block-granular hit, decodes token-identical to its cold run, and
    evicting the cached blocks restores the cold path."""
    _, m, params, prompts = served
    p1 = np.asarray(prompts[0])  # 16 tokens
    p2 = np.concatenate([p1[:12], np.asarray(prompts[1, :4])])  # 75% shared

    cold = ServingEngine(m, params, max_seq=64, kv_layout="paged", prefix_cache=False)
    cold1 = cold.serve([r := Request(prompt=p1, max_new_tokens=4)], max_batch=2)[r.rid]
    cold2 = cold.serve([r := Request(prompt=p2, max_new_tokens=4)], max_batch=2)[r.rid]
    assert r.prefix_hit == 0

    eng = ServingEngine(m, params, max_seq=64, kv_layout="paged", block_size=4)
    sched = eng.scheduler(2)
    r1 = Request(prompt=p1, max_new_tokens=4)
    r2 = Request(prompt=p2, max_new_tokens=4)
    out = sched.run([r1, r2])  # same wave: r2 admits after r1 registers
    assert r1.prefix_hit == 0
    assert r2.prefix_hit == 12  # 3 shared blocks of 4 = the 75% prefix
    np.testing.assert_array_equal(out[r1.rid], cold1)
    np.testing.assert_array_equal(out[r2.rid], cold2)
    assert eng.stats.n_prefix_hits == 1
    assert eng.stats.prefix_hit_tokens == 12
    assert eng.stats.serving_summary()["prefix_hit_rate"] == pytest.approx(12 / 32)

    # retired prompts stay cached (parked): a re-run of p2 hits its own
    # full-block prefix now, not just the shared 12
    r3 = Request(prompt=p2, max_new_tokens=4)
    out3 = sched.run([r3])
    assert r3.prefix_hit == 12  # cap (16-1)//4 = 3 blocks
    np.testing.assert_array_equal(out3[r3.rid], cold2)

    # eviction after free restores the cold path exactly
    assert sched.kv.drop_cached() > 0
    sched.kv.check_invariants()
    r4 = Request(prompt=p2, max_new_tokens=4)
    out4 = sched.run([r4])
    assert r4.prefix_hit == 0
    np.testing.assert_array_equal(out4[r4.rid], cold2)


def test_prefix_reuse_across_staggered_arrivals_drops_prefill_cost(served):
    """Later arrivals over a common prompt header hit the cache while the
    first holder is still decoding (live sharing, refcount > 1)."""
    _, m, params, prompts = served
    head = np.asarray(prompts[2])  # 16-token shared header
    reqs = [
        Request(
            prompt=np.concatenate([head, np.asarray(prompts[3 + i, :4])]),
            max_new_tokens=6,
            arrival_time=0.005 * i,
        )
        for i in range(3)
    ]
    eng = ServingEngine(m, params, max_seq=64, kv_layout="paged", block_size=4)
    sched = eng.scheduler(4)
    sched.run(reqs)
    assert reqs[0].prefix_hit == 0
    assert all(r.prefix_hit == 16 for r in reqs[1:])  # whole shared header
    assert eng.stats.prefix_hit_rate == pytest.approx(32 / 60)
    sched.kv.check_invariants()


# ---------------------------------------------------------------------------
# block-granular admission control


def test_submit_rejects_block_budget_beyond_pool(served):
    """A request whose block need can never fit the pool is rejected at
    submit() — in blocks, not tokens — instead of deadlocking the FIFO
    queue."""
    _, m, params, _ = served
    eng = ServingEngine(
        m, params, max_seq=32, kv_layout="paged", block_size=4, num_blocks=4
    )
    req = Request(prompt=jnp.ones((20,), jnp.int32), max_new_tokens=8)
    with pytest.raises(ValueError, match=r"needs 7 KV blocks .* 4 blocks total"):
        eng.serve([req], max_batch=1)
    # row capacity still guards first (max_seq semantics preserved)
    with pytest.raises(ValueError, match="max_seq"):
        eng.serve([Request(prompt=jnp.ones((30,), jnp.int32), max_new_tokens=8)], max_batch=1)


def test_paged_scheduler_rejects_decode_plan(served):
    _, m, params, _ = served
    from repro.core.plan import plan_for

    plan = plan_for("paged-no-plan", lambda x: x, jnp.arange(4.0), granularity=1)
    eng = ServingEngine(m, params, max_seq=32, kv_layout="paged")
    eng.set_decode_plan(plan)
    with pytest.raises(ValueError, match="slotted layout"):
        eng.scheduler(2)
