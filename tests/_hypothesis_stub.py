"""Deterministic stand-in for the slice of hypothesis the suite uses.

The container may not ship hypothesis; rather than skip the property
tests, this shim runs each one over the strategy corners (lo/hi or the
full sampled_from list) plus seeded-random interior samples, honoring
``max_examples``. Shrinking, stateful testing, etc. are out of scope —
install real hypothesis to get them.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw, edges=()):
        self._draw = draw
        self.edges = list(edges)

    def example(self, rng, i):
        if i < len(self.edges):
            return self.edges[i]
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: rng.randint(min_value, max_value),
            edges=[min_value, max_value],
        )

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: rng.uniform(min_value, max_value),
            edges=[min_value, max_value],
        )

    @staticmethod
    def sampled_from(elements):
        xs = list(elements)
        return _Strategy(lambda rng: xs[rng.randrange(len(xs))], edges=xs)


st = strategies


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        # no functools.wraps: pytest must NOT see the drawn parameters
        # (it would look for same-named fixtures)
        def wrapper():
            n = getattr(wrapper, "_max_examples", 20)
            rng = random.Random(1234)
            for i in range(n):
                drawn = {k: s.example(rng, i) for k, s in strats.items()}
                fn(**drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
