"""relic_pfor edge cases: granularity > n_items, padding paths, the
round-robin deal/undeal order property, and combine semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.relic import relic_pfor

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st


def test_granularity_larger_than_n_items():
    """g > n clamps to one chunk of all items (plus stream padding)."""
    fn = lambda x: x * 2.0 + 1.0
    xs = jnp.arange(5, dtype=jnp.float32)
    got = relic_pfor(fn, xs, granularity=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(jax.vmap(fn)(xs)))


def test_granularity_zero_clamps_to_one():
    fn = lambda x: x - 3.0
    xs = jnp.arange(7, dtype=jnp.float32)
    got = relic_pfor(fn, xs, granularity=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(jax.vmap(fn)(xs)))


@pytest.mark.parametrize("n,g,streams", [
    (10, 3, 2),   # n % g != 0
    (12, 3, 4),   # n_chunks % n_streams == 0, exact
    (13, 3, 4),   # both padding conditions
    (8, 3, 3),    # odd stream count
    (2, 1, 4),    # fewer items than streams
    (1, 1, 2),    # single item
])
def test_padding_path_preserves_items(n, g, streams):
    """n_chunks not divisible by n_streams → padded; padding must never
    leak into the stacked result."""
    fn = lambda x: jnp.stack([x, x * x])
    xs = jnp.arange(n, dtype=jnp.float32) + 1.0
    got = relic_pfor(fn, xs, granularity=g, n_streams=streams)
    want = jax.vmap(fn)(xs)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 200),
    g=st.integers(1, 64),
    streams=st.sampled_from([1, 2, 3, 4]),
)
def test_round_robin_deal_undeal_is_identity(n, g, streams):
    """Property: dealing chunks round-robin to streams and undealing
    restores the original item order exactly (fn = identity on the item
    index)."""
    idx = jnp.arange(n, dtype=jnp.int32)
    got = relic_pfor(lambda i: i, idx, granularity=g, n_streams=streams)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(idx))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 150), g=st.integers(1, 40))
def test_combine_sum_equals_vmap_sum(n, g):
    """Property: combine="sum" is the tree-sum of per-item results, with
    padding items masked out."""
    fn = lambda x: {"a": x * 2.0, "b": jnp.stack([x, -x])}
    xs = jnp.arange(n, dtype=jnp.float32) + 1.0
    got = relic_pfor(fn, xs, granularity=g, combine="sum")
    want = jax.tree.map(lambda y: y.sum(0), jax.vmap(fn)(xs))
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-4
        ),
        got,
        want,
    )


def test_combine_sum_masks_padding():
    """5 items at granularity 16: the pad repeats item 4 eleven times —
    an unmasked sum would be wildly wrong."""
    fn = lambda x: x
    xs = jnp.full((5,), 100.0)
    got = relic_pfor(fn, xs, granularity=16, combine="sum")
    np.testing.assert_allclose(float(got), 500.0)


def test_combine_sum_under_jit():
    fn = lambda x: x * x
    xs = jnp.arange(33, dtype=jnp.float32)
    f = jax.jit(lambda a: relic_pfor(fn, a, granularity=4, combine="sum"))
    np.testing.assert_allclose(float(f(xs)), float((xs * xs).sum()), rtol=1e-6)


def test_invalid_combine_rejected():
    with pytest.raises(ValueError, match="combine"):
        relic_pfor(lambda x: x, jnp.arange(4.0), granularity=2, combine="mean")


def test_benchmarks_declare_sum_and_plan_honors_it():
    """Benchmark.parallel_value(combine="sum") (the plan-layer path)
    equals the combined serial value."""
    from repro.bench_suite import BENCHMARKS

    b = BENCHMARKS["VWAP"]
    data = b.build()
    assert b.combine == "sum"
    got = b.parallel_value(data, granularity=8, combine=b.combine)
    want = b.serial_value(data, combine=b.combine)
    jax.tree.map(
        lambda a, w: np.testing.assert_allclose(
            np.asarray(a), np.asarray(w), rtol=1e-4, atol=1e-4
        ),
        got,
        want,
    )
