"""Property tests for the ShardingRules drop-rule over random (cfg, mesh)
pairs: both drop paths — divisibility and already-used mesh axis — must
leave a correctly-named fallback record, and the resulting specs must
never double-assign a mesh axis or assign a non-dividing one.

Runs against a duck-typed mesh (only ``mesh.shape`` is consulted by
``spec()``), so the random mesh shapes need no real devices."""
import dataclasses
import math

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_config
from repro.parallel.sharding import ShardingRules


class _FakeMesh:
    """shape-only stand-in (spec() never touches devices)."""

    def __init__(self, shape):
        self.shape = dict(shape)


def _cfg(n_heads, n_kv_heads):
    return dataclasses.replace(
        get_config("smollm-135m").reduced(),
        n_heads=n_heads, n_kv_heads=n_kv_heads,
    )


def _flat_axes(entry):
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


@settings(max_examples=40, deadline=None)
@given(
    n_heads=st.integers(1, 16),
    n_kv=st.integers(1, 8),
    model=st.sampled_from([1, 2, 3, 4, 8]),
    data=st.sampled_from([1, 2, 4]),
    mult=st.integers(1, 3),
)
def test_spec_invariants_random_cfg_mesh(n_heads, n_kv, model, data, mult):
    """Over random (cfg, mesh): every emitted spec assigns each mesh axis
    at most once, every assignment divides its dimension, and every drop
    is recorded under the dropped dimension's own logical name."""
    cfg = _cfg(n_heads, n_kv)
    mesh = _FakeMesh({"data": data, "model": model})
    annotations = [
        (("embed", "heads", "head_dim"), (cfg.d_model, n_heads, 16)),
        (("embed", "kv_heads", "head_dim"), (cfg.d_model, n_kv, 16)),
        ((None, "batch", "kv_seq", "kv_heads", None), (2, 4, 32, n_kv, 16)),
        (("batch", "seq_sp", "heads", None), (data * 2, model * mult, n_heads, 16)),
        (("mlp", "vocab"), (model * mult, model * mult)),
    ]
    for axes, shape in annotations:
        rules = ShardingRules(mesh, cfg)
        before = len(rules.fallbacks)
        spec = rules.spec(axes, shape)
        seen = []
        for entry, dim, name in zip(spec, shape, axes):
            flat = _flat_axes(entry)
            seen.extend(flat)
            if flat:
                size = math.prod(mesh.shape[a] for a in flat)
                assert dim % size == 0, (axes, shape, spec)
        assert len(seen) == len(set(seen)), f"mesh axis assigned twice: {spec}"
        # every recorded drop names a logical axis of THIS array
        for rec in rules.fallbacks[before:]:
            logical = rec.split(":", 1)[0]
            assert logical in [a for a in axes if a], rec


@settings(max_examples=25, deadline=None)
@given(model=st.sampled_from([1, 2, 3, 4, 8]), mult=st.integers(1, 3))
def test_already_used_drop_records_later_axis_name(model, mult):
    """The already-used drop path: when an earlier dimension consumed the
    mesh axis, the LATER logical axis is dropped — and the record must
    carry the later axis's name (the satellite bug: it reported the
    wrong one)."""
    cfg = _cfg(4, 2)
    rules = ShardingRules(_FakeMesh({"data": 2, "model": model}), cfg)
    dim = model * mult
    spec = rules.spec(("mlp", "vocab"), (dim, dim))
    assert spec[0] == "model" and spec[1] is None
    recs = [r for r in rules.fallbacks if "already used" in r]
    assert recs, rules.fallbacks
    assert recs[0].startswith(f"vocab:{dim}"), recs
    assert "mlp" not in recs[0], recs


@settings(max_examples=25, deadline=None)
@given(model=st.sampled_from([2, 3, 4, 8]), off=st.integers(1, 3))
def test_divisibility_drop_records_axis_name(model, off):
    """The divisibility drop path: a dimension the mesh axis does not
    divide falls back to unsharded with a record naming that dimension."""
    cfg = _cfg(4, 2)
    rules = ShardingRules(_FakeMesh({"data": 1, "model": model}), cfg)
    dim = model + off if (model + off) % model else model + off + 1
    assert dim % model != 0
    spec = rules.spec(("mlp",), (dim,))
    assert spec[0] is None
    assert any(r.startswith(f"mlp:{dim}") and "∤" in r for r in rules.fallbacks), (
        rules.fallbacks
    )


def test_mesh_without_data_axis_is_not_a_fallback():
    """A serving-only ('model',) mesh simply lacks the 'data'/'pod' axes:
    batch stays unsharded with NO fallback record and NO KeyError."""
    cfg = _cfg(4, 4)
    rules = ShardingRules(_FakeMesh({"model": 2}), cfg)
    spec = rules.spec(("batch", "seq", None), (8, 16, 32))
    assert tuple(spec) == (None, None, None)
    assert rules.fallbacks == []
    # the head axes still shard normally on the same mesh
    spec = rules.spec(("embed", "kv_heads", "head_dim"), (64, 4, 16))
    assert spec[1] == "model"
