"""Chunked prefill: token identity with monolithic prefill (both KV
layouts, with and without speculation), the closed pow2 trace family
(no retrace within a bucket, for chunk steps and bucketed monolithic
prefill alike), and the config gates around the chunked path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models.model import CHUNKED_PREFILL_FAMILIES, prefill_bucket
from repro.serve import Request, ServingEngine, SpecConfig


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    return cfg, m, params


def _workload(cfg, lens=(13, 5, 29, 8, 17), priorities=(0, 1, 0, 1, 1), tokens=6):
    reqs = []
    for i, (s0, pr) in enumerate(zip(lens, priorities)):
        prompt = np.random.default_rng(100 + i).integers(
            0, cfg.vocab_size, size=(s0,)
        ).astype(np.int32)
        reqs.append(
            Request(
                prompt=prompt, max_new_tokens=tokens,
                arrival_time=0.005 * i, priority=pr,
            )
        )
    return reqs


# ---------------------------------------------------------------------------
# pow2 bucketing


def test_prefill_bucket():
    assert [prefill_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 16, 17)] == [
        1, 2, 4, 4, 8, 8, 16, 16, 32,
    ]
    assert prefill_bucket(9, cap=8) == 8  # chunk slices never exceed the budget
    assert prefill_bucket(3, cap=8) == 4


# ---------------------------------------------------------------------------
# token identity: chunked == monolithic


@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_chunked_equals_monolithic(served, layout):
    """Chunking moves prefill work across steps, never tokens: an
    identical staggered mixed-priority workload decodes bitwise the
    same whether prompts prefill monolithically or in 8-token chunks."""
    cfg, m, params = served
    eng = ServingEngine(m, params, max_seq=128, kv_layout=layout, max_batch=3)
    mono_reqs = _workload(cfg)
    mono = eng.serve(mono_reqs, chunk_size=0)
    chunk_reqs = _workload(cfg)
    chunked = eng.serve(chunk_reqs, chunk_size=8)
    assert all(r.finished for r in mono_reqs + chunk_reqs)
    for a, b in zip(mono_reqs, chunk_reqs):
        np.testing.assert_array_equal(mono[a.rid], chunked[b.rid])


@pytest.mark.parametrize("layout", ["slot", "paged"])
def test_chunked_equals_monolithic_with_speculation(served, layout):
    """Chunked prefill composes with speculative decoding: draft
    streams catch up at install time, and the greedy stream is still
    bitwise the plain monolithic one."""
    cfg, m, params = served
    eng = ServingEngine(m, params, max_seq=128, kv_layout=layout, max_batch=3)
    mono_reqs = _workload(cfg)
    mono = eng.serve(mono_reqs, chunk_size=0, spec=SpecConfig(k=0))
    spec_reqs = _workload(cfg)
    spec = eng.serve(
        spec_reqs, chunk_size=8, spec=SpecConfig(k=4, drafter="ngram")
    )
    for a, b in zip(mono_reqs, spec_reqs):
        np.testing.assert_array_equal(mono[a.rid], spec[b.rid])


def test_chunked_prefix_reuse_token_identity(served):
    """Shared-prefix prompts through the chunked path: the second
    request seeds its chunk cache from the trie hit and still decodes
    identically to the monolithic engine (and actually hits)."""
    cfg, m, params = served
    header = np.random.default_rng(7).integers(0, cfg.vocab_size, size=(24,))
    def reqs():
        out = []
        for i in range(3):
            tail = np.random.default_rng(50 + i).integers(
                0, cfg.vocab_size, size=(6,)
            )
            out.append(Request(
                prompt=np.concatenate([header, tail]).astype(np.int32),
                max_new_tokens=4, arrival_time=0.05 * i,
            ))
        return out

    eng = ServingEngine(m, params, max_seq=96, kv_layout="paged",
                        block_size=8, max_batch=2)
    mono_reqs = reqs()
    mono = eng.serve(mono_reqs, chunk_size=0)
    chunk_reqs = reqs()
    chunked = eng.serve(chunk_reqs, chunk_size=8)
    for a, b in zip(mono_reqs, chunk_reqs):
        np.testing.assert_array_equal(mono[a.rid], chunked[b.rid])
    assert any(r.prefix_hit > 0 for r in chunk_reqs[1:])


# ---------------------------------------------------------------------------
# trace family: one trace per pow2 bucket, no retrace across positions


def test_prefill_chunk_no_retrace_across_positions(served):
    """Every chunk of a given bucket width reuses ONE trace no matter
    where in the prompt it lands — the chunk position rides in the
    cache's ``len`` (data), not in any shape."""
    cfg, m, params = served
    traces = []

    @jax.jit
    def chunk_fn(p, cache, toks, n):
        traces.append(1)
        return m.prefill_chunk(p, cache, toks, n)

    cache = m.init_cache(1, 64)
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, size=(40,))
    pos = 0
    while pos < len(prompt):
        n = min(8, len(prompt) - pos)
        toks = np.zeros((1, 8), np.int32)
        toks[0, :n] = prompt[pos : pos + n]
        _, cache = chunk_fn(
            params, cache, jnp.asarray(toks), jnp.asarray([n], jnp.int32)
        )
        pos += n
    assert len(traces) == 1  # five chunks at five offsets, one trace
    assert int(cache["len"][0]) == len(prompt)


def test_monolithic_bucketed_prefill_no_retrace(served):
    """All prompt lengths inside one pow2 bucket share a single padded
    prefill trace; crossing a bucket boundary costs exactly one more."""
    cfg, m, params = served
    traces = []

    @jax.jit
    def prefill_fn(p, toks, n):
        traces.append(1)
        return m.prefill(p, toks, 64, prompt_len=n)

    for s0 in (9, 11, 14, 16):  # all bucket to W=16
        W = prefill_bucket(s0)
        assert W == 16
        toks = np.zeros((1, W), np.int32)
        toks[0, :s0] = np.arange(s0) % cfg.vocab_size
        _, cache = prefill_fn(
            params, jnp.asarray(toks), jnp.asarray([s0], jnp.int32)
        )
        assert int(cache["len"][0]) == s0  # pad rows never commit
    assert len(traces) == 1
    _ = prefill_fn(
        params, jnp.zeros((1, 32), jnp.int32), jnp.asarray([20], jnp.int32)
    )
    assert len(traces) == 2  # next bucket, one new trace


def test_bucketed_prefill_matches_exact(served):
    """Padded+masked prefill is bitwise the exact-shape prefill: same
    next-token logits, same committed KV rows and length."""
    cfg, m, params = served
    s0 = 11
    prompt = np.random.default_rng(3).integers(0, cfg.vocab_size, size=(1, s0))
    exact_logits, exact_cache = m.prefill(params, jnp.asarray(prompt), 64)
    W = prefill_bucket(s0)
    padded = np.zeros((1, W), np.int64)
    padded[:, :s0] = prompt
    pad_logits, pad_cache = m.prefill(
        params, jnp.asarray(padded), 64, prompt_len=jnp.asarray([s0])
    )
    np.testing.assert_array_equal(np.asarray(exact_logits), np.asarray(pad_logits))
    assert int(pad_cache["len"][0]) == int(exact_cache["len"][0]) == s0
    np.testing.assert_array_equal(  # committed KV rows identical too
        np.asarray(exact_cache["k"][:, :, :s0]), np.asarray(pad_cache["k"][:, :, :s0])
    )


# ---------------------------------------------------------------------------
# config gates


def test_chunk_size_must_be_pow2(served):
    cfg, m, params = served
    eng = ServingEngine(m, params, max_seq=64, max_batch=2)
    with pytest.raises(ValueError, match="power of two"):
        eng.serve([Request(prompt=np.arange(4), max_new_tokens=2)], chunk_size=6)


def test_chunked_rejects_unsupported_family(served):
    """``prefill_chunk`` is gated to families whose decode-cache path
    is pad-safe AND position-indifferent; an SSM hybrid is neither."""
    cfg, m, params = served
    ssm_cfg = dataclasses.replace(cfg, family="ssm")
    assert ssm_cfg.family not in CHUNKED_PREFILL_FAMILIES
    ssm = Model(ssm_cfg)
    with pytest.raises(ValueError, match="chunked prefill"):
        ssm.prefill_chunk(
            params, m.init_cache(1, 16), jnp.zeros((1, 4), jnp.int32),
            jnp.asarray([4], jnp.int32),
        )


def test_chunked_rejects_patch_embeds(served):
    """VLM patch embeddings ride the monolithic path only: submitting
    one to a chunked scheduler is refused (and counted)."""
    cfg, m, params = served
    eng = ServingEngine(m, params, max_seq=64, max_batch=2)
    sched = eng.scheduler(2, chunk_size=8)
    with pytest.raises(ValueError, match="chunk"):
        sched.submit(
            Request(
                prompt=np.arange(4), max_new_tokens=2,
                patch_embeds=np.zeros((2, cfg.d_model), np.float32),
            )
        )
    assert eng.stats.rejected_submissions == 1
