"""Continuous-batching serving core: slot alloc/free invariants, masked
plan execution (no retrace across live counts, mask correctness),
fixed-batch vs continuous-batch token equivalence, per-request latency
accounting, and the stack-combine contract of ``set_decode_plan``."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Aira, Workload, clear_plan_cache
from repro.core.plan import plan_for
from repro.core.relic import relic_pfor
from repro.models import Model
from repro.serve import Request, ServingEngine, SlotKVCache


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab_size)
    return cfg, m, params, prompts


# ---------------------------------------------------------------------------
# slot pool


def test_slot_alloc_free_invariants_random_order(served):
    """Random admit/finish sequences preserve the pool partition: every
    slot is exactly one of {free, live}, no double alloc/free, freed
    slots are reusable, lowest-free-first allocation is deterministic."""
    _, m, _, _ = served
    kv = SlotKVCache(m, max_batch=4, max_seq=16)
    rng = random.Random(0)
    live: dict[int, int] = {}  # slot → rid
    rid = 0
    for _ in range(300):
        if kv.n_free and (not live or rng.random() < 0.5):
            slot = kv.alloc(rid)
            assert slot not in live
            assert slot == min(set(range(4)) - set(live))  # lowest free
            assert kv.owner(slot) == rid
            live[slot] = rid
            rid += 1
        else:
            slot = rng.choice(sorted(live))
            kv.free(slot)
            del live[slot]
        kv.check_invariants()
        assert kv.n_live == len(live)
        np.testing.assert_array_equal(
            kv.live_mask(), [s in live for s in range(4)]
        )
    if not live:
        live[kv.alloc(rid)] = rid
    slot = rng.choice(sorted(live))
    kv.free(slot)
    with pytest.raises(RuntimeError, match="double free"):
        kv.free(slot)


def test_slot_pool_exhaustion_and_write_guard(served):
    _, m, _, _ = served
    kv = SlotKVCache(m, max_batch=2, max_seq=16)
    kv.alloc(0), kv.alloc(1)
    with pytest.raises(RuntimeError, match="free cache slot"):
        kv.alloc(2)
    kv.free(0)
    with pytest.raises(RuntimeError, match="free slot"):
        kv.write(0, kv.read(1))  # slot 0 no longer live


def test_slot_write_read_roundtrip(served):
    """A request's prefill cache written into a slot reads back intact."""
    _, m, params, prompts = served
    _, cache1 = m.prefill(params, prompts[1:2], 16)
    kv = SlotKVCache(m, max_batch=3, max_seq=16)
    slot = kv.alloc(7)
    kv.write(slot, cache1)
    back = kv.read(slot)
    for got, want in zip(jax.tree.leaves(back), jax.tree.leaves(cache1)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# continuous batching == fixed batch (greedy, token-for-token)


def test_half_full_continuous_batch_matches_fixed_batch(served):
    _, m, params, prompts = served
    eng = ServingEngine(m, params, max_seq=64)
    base = eng.generate(prompts[:2], n_steps=4)
    eng2 = ServingEngine(m, params, max_seq=64)
    reqs = [
        Request(prompt=prompts[i], max_new_tokens=4, arrival_time=0.02 * i)
        for i in range(2)
    ]
    out = eng2.serve(reqs, max_batch=4)  # pool stays half full
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(out[r.rid], np.asarray(base[i]))
        assert r.finished and r.ttft_ms is not None and r.e2e_ms is not None
    assert len(eng2.stats.ttft_ms) == 2


def test_staggered_lengths_and_slot_reuse(served):
    """3 requests with different prompt lengths and budgets through a
    2-slot pool: the third is admitted into a freed slot while others
    are mid-decode (divergent per-slot cache lengths), and every output
    matches its single-request baseline."""
    _, m, params, prompts = served
    lens, budgets = (5, 8, 6), (3, 5, 4)
    reqs = [
        Request(
            prompt=prompts[i, : lens[i]],
            max_new_tokens=budgets[i],
            arrival_time=0.01 * i,
        )
        for i in range(3)
    ]
    eng = ServingEngine(m, params, max_seq=64)
    out = eng.serve(reqs, max_batch=2)
    for i, r in enumerate(reqs):
        base = ServingEngine(m, params, max_seq=64).generate(
            prompts[i : i + 1, : lens[i]], n_steps=budgets[i]
        )
        np.testing.assert_array_equal(out[r.rid], np.asarray(base[0]))


def test_eos_finishes_early_and_frees_slot(served):
    _, m, params, prompts = served
    base = ServingEngine(m, params, max_seq=64).generate(prompts[:1], n_steps=4)
    eos = int(base[0, 2])
    eng = ServingEngine(m, params, max_seq=64)
    req = Request(prompt=prompts[0], max_new_tokens=16, eos_id=eos)
    out = eng.serve([req], max_batch=2)
    np.testing.assert_array_equal(out[req.rid], np.asarray(base[0, :3]))
    assert req.finished


# ---------------------------------------------------------------------------
# masked plan execution


def test_masked_relic_stack_and_sum():
    fn = lambda x: x * 2.0 + 1.0
    items = jnp.arange(10, dtype=jnp.float32)
    mask = jnp.array([1, 1, 0, 1, 0, 0, 1, 1, 1, 0], bool)
    out = relic_pfor(fn, items, granularity=2, valid=mask)
    np.testing.assert_array_equal(
        np.asarray(out), np.where(np.asarray(mask), np.asarray(fn(items)), 0.0)
    )
    s = relic_pfor(lambda x: x, items, granularity=4, combine="sum", valid=mask)
    np.testing.assert_allclose(float(s), float(items[mask].sum()))


def test_execute_masked_single_trace_across_live_counts():
    """The mask is data, not shape: changing the number of live items
    must not retrace the plan's compiled region."""
    clear_plan_cache()
    traces = []

    def fn(x):  # python side effect fires at trace time only
        traces.append(1)
        return x + 1.0

    items = jnp.arange(12, dtype=jnp.float32)
    plan = plan_for("masked-trace-count", fn, items, granularity=2)
    for n_live in (3, 7, 12, 1):
        mask = jnp.arange(12) < n_live
        got = plan.execute_masked(items, mask)
        np.testing.assert_array_equal(
            np.asarray(got), np.where(np.asarray(mask), np.asarray(items + 1.0), 0.0)
        )
    assert len(traces) == 1, "masked plan execution retraced on live-count change"


def test_masked_plan_decode_matches_plain_partial_batch(served):
    """Plan-decode == plain-decode with a partially full pool: the
    accepted RegionPlan, executed masked over the active-slot view,
    reproduces the unplanned scheduler token-for-token."""
    _, m, params, prompts = served
    eng = ServingEngine(m, params, max_seq=64)
    region = eng.decode_region(prompts, force=True, seed=3)
    d = Aira().advise(Workload("serve-mask", lambda: None, [region])).decisions[0]
    assert d.accepted and d.plan is not None

    def staggered():
        return [
            Request(prompt=prompts[i], max_new_tokens=3 + i, arrival_time=0.01 * i)
            for i in range(2)
        ]

    plain_reqs = staggered()
    plain = ServingEngine(m, params, max_seq=64).serve(plain_reqs, max_batch=4)
    eng2 = ServingEngine(m, params, max_seq=64, decode_plan=d.plan)
    plan_reqs = staggered()
    planned = eng2.serve(plan_reqs, max_batch=4)
    for rp, rq in zip(plain_reqs, plan_reqs):
        np.testing.assert_array_equal(plain[rp.rid], planned[rq.rid])


def test_scheduler_rejects_sum_combine_plan(served):
    _, m, params, _ = served
    eng = ServingEngine(m, params, max_seq=64)
    bad = plan_for("bad-sum", lambda x: x, jnp.arange(4.0), granularity=1, combine="sum")
    with pytest.raises(ValueError, match="stack"):
        eng.set_decode_plan(bad)
    with pytest.raises(ValueError, match="stack"):
        eng.scheduler(2).set_decode_plan(bad)


def test_submit_rejects_over_capacity_request(served):
    """Prompt + budget beyond max_seq would clamp cache writes and
    silently corrupt tokens — submission must fail loudly instead."""
    _, m, params, prompts = served
    eng = ServingEngine(m, params, max_seq=16)
    with pytest.raises(ValueError, match="max_seq"):
        eng.serve(
            [Request(prompt=jnp.ones((12,), jnp.int32), max_new_tokens=8)],
            max_batch=1,
        )


def test_make_requests_handles_budget_of_one():
    from repro.serve.load import make_requests

    reqs = make_requests(
        3, 100.0, vocab=50, max_new_tokens=1, rng=np.random.default_rng(0)
    )
    assert all(r.max_new_tokens == 1 for r in reqs)


# ---------------------------------------------------------------------------
# stats lifecycle


def test_serving_summary_explicit_when_no_request_finished():
    """A run where zero requests finish yields an explicit empty
    summary (empty=True, None latencies) — not 0 ms percentiles over
    empty series — while step timings, measured per decode, survive."""
    from repro.serve import ServeStats

    s = ServeStats()
    out = s.serving_summary()
    assert out["empty"] and out["n_requests"] == 0
    assert out["p50_ttft_ms"] is None and out["p99_e2e_ms"] is None
    assert out["p50_step_ms"] is None  # no steps either
    s.step_ms.extend([1.0, 2.0])  # steps ran, but nothing retired yet
    out = s.serving_summary()
    assert out["empty"] and out["p50_ttft_ms"] is None
    assert out["p50_step_ms"] == 1.5 and out["n_steps"] == 2


def test_stats_reset_per_run(served):
    _, m, params, prompts = served
    eng = ServingEngine(m, params, max_seq=64)
    eng.generate(prompts[:2], n_steps=3)
    first = list(eng.stats.step_ms)
    assert len(first) == 2  # n_steps - 1 decode steps
    eng.generate(prompts[:2], n_steps=3)
    assert len(eng.stats.step_ms) == 2  # clean per run, no accumulation
    assert len(eng.stats.ttft_ms) == 2 and len(eng.stats.e2e_ms) == 2
    assert eng.stats.percentile(50) > 0
    s = eng.stats.serving_summary()
    assert s["n_requests"] == 2 and s["p99_ttft_ms"] >= s["p50_ttft_ms"] >= 0
    eng.stats.reset()
    assert eng.stats.summary().startswith("steps=0")
