"""Sharded-serving differential lanes (DESIGN.md §5). Head-only
("model") meshes must produce BITWISE the token streams of the
single-device paged path — head partitioning only moves parallel work,
never changes a reduction order. kv-sequence-split meshes ("seq" and 2D
("model","seq")) recombine softmaxes from per-rank flash partials, so
their lane is tolerance-based: argmax token identity plus a
max-abs-logit bound (``repro.serve.differential``). Each test runs in a
subprocess with a forced 4-device CPU host platform so the main pytest
process keeps its single real device."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=ROOT, timeout=600,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr}\nstdout:\n{r.stdout}"
    assert "PASS" in r.stdout, r.stdout


def _header(tp: int) -> str:
    return f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.model import Model
from repro.serve.engine import ServingEngine
from repro.serve.request import Request
from repro.serve.speculative import SpecConfig
try:
    mesh = jax.make_mesh(({tp},), ("model",), axis_types=(jax.sharding.AxisType.Auto,))
except AttributeError:  # jax 0.4.x: no AxisType
    mesh = jax.make_mesh(({tp},), ("model",))

# 4 kv heads so both 2- and 4-way meshes divide; g=2 exercises GQA grouping
CFG = dataclasses.replace(
    get_config("smollm-135m").reduced(),
    num_layers=2, d_model=64, n_heads=8, n_kv_heads=4, head_dim=8,
)

def build(cfg):
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    return m, params

def reqs(n=4, plen=6, new=8, **kw):
    return [
        Request(prompt=(np.arange(plen, dtype=np.int32) * (i + 1)) % cfg_vocab,
                max_new_tokens=new, **kw)
        for i in range(n)
    ]
cfg_vocab = CFG.vocab_size

def identical(a, b):
    # rids are globally auto-assigned, so match streams by admission order
    assert len(a) == len(b)
    for (_, va), (_, vb) in zip(sorted(a.items()), sorted(b.items())):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
"""


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_token_identity_modes(tp):
    """plain / speculative K∈{2,4} / chunked-prefill serving over a
    tp-way head-partitioned pool == the single-device paged path,
    bitwise, through the interpret (real kernel code) backend."""
    _run(_header(tp) + """
m, params = build(CFG)
base = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                     attention_backend="interpret")
sharded = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                        attention_backend="kernel", mesh=mesh)
assert sharded.mesh is mesh
assert sharded.attention_backend == "interpret"  # mesh-aware resolution

# the pool really is head-partitioned over the mesh
sched = sharded.scheduler(4)
spec = sched.kv.pool["k"].sharding.spec
assert "model" in tuple(spec), spec

identical(base.serve(reqs(), max_batch=4), sharded.serve(reqs(), max_batch=4))
for K in (2, 4):
    identical(base.serve(reqs(), max_batch=4, spec=SpecConfig(k=K)),
              sharded.serve(reqs(), max_batch=4, spec=SpecConfig(k=K)))
identical(base.serve(reqs(plen=12), max_batch=4, chunk_size=4),
          sharded.serve(reqs(plen=12), max_batch=4, chunk_size=4))
print("PASS")
""")


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_token_identity_int8_kv(tp):
    """int8-KV pool (values + per-vector scales both head-partitioned)
    decodes and verifies bitwise-identically to single-device int8."""
    _run(_header(tp) + """
cfg8 = dataclasses.replace(CFG, kv_quant=True)
m, params = build(cfg8)
base = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                     attention_backend="interpret")
sharded = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                        attention_backend="interpret", mesh=mesh)
identical(base.serve(reqs(), max_batch=4), sharded.serve(reqs(), max_batch=4))
identical(base.serve(reqs(), max_batch=4, spec=SpecConfig(k=2)),
          sharded.serve(reqs(), max_batch=4, spec=SpecConfig(k=2)))
print("PASS")
""")


def _mesh_header(shape, names) -> str:
    """Header with an arbitrary serving mesh (e.g. ``(2, 2)`` over
    ``("model", "seq")``) instead of the head-only one."""
    return _header(2).replace(
    	'jax.make_mesh((2,), ("model",), axis_types=(jax.sharding.AxisType.Auto,))',
    	f'jax.make_mesh({shape!r}, {names!r}, '
    	f'axis_types=(jax.sharding.AxisType.Auto,) * {len(shape)})',
    ).replace(
    	'jax.make_mesh((2,), ("model",))',
    	f'jax.make_mesh({shape!r}, {names!r})',
    )


@pytest.mark.parametrize(
    "shape,names",
    [((2,), ("model",)), ((2,), ("seq",)), ((2, 2), ("model", "seq"))],
    ids=["model2", "seq2", "model2xseq2"],
)
def test_mesh_shapes_token_identity(shape, names):
    """The serve-level differential over every mesh family the engine
    supports: head-only (bitwise lane), kv-sequence split, and the 2D
    composition — plain, speculative K=2, and chunked prefill all match
    the single-device paged streams (tolerance lane's argmax token
    identity; greedy tokens ARE the argmax)."""
    _run(_mesh_header(shape, names) + """
from repro.serve.differential import assert_streams_equal
m, params = build(CFG)
base = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                     attention_backend="interpret")
sharded = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                        attention_backend="interpret", mesh=mesh)
assert sharded.mesh is mesh
sched = sharded.scheduler(4)
spec = tuple(sched.kv.pool["k"].sharding.spec)
for ax in mesh.axis_names:
    if mesh.shape[ax] > 1:
        assert ax in spec, (ax, spec)  # pool really partitioned on ax
assert_streams_equal(base.serve(reqs(), max_batch=4),
                     sharded.serve(reqs(), max_batch=4), label="plain")
assert_streams_equal(
    base.serve(reqs(), max_batch=4, spec=SpecConfig(k=2)),
    sharded.serve(reqs(), max_batch=4, spec=SpecConfig(k=2)), label="spec")
assert_streams_equal(
    base.serve(reqs(plen=12), max_batch=4, chunk_size=4),
    sharded.serve(reqs(plen=12), max_batch=4, chunk_size=4), label="chunked")
print("PASS")
""")


def test_seq_split_reference_backend():
    """The reference backend must route through the partials path under
    the kv-sequence split (the dense differential route gathers through
    global tables, which cannot address a local pool shard) — pinned by
    serving through a pure-"seq" mesh with backend="reference"."""
    _run(_mesh_header((2,), ("seq",)) + """
from repro.serve.differential import assert_streams_equal
m, params = build(CFG)
base = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                     attention_backend="reference")
sharded = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                        attention_backend="reference", mesh=mesh)
assert_streams_equal(base.serve(reqs(), max_batch=4),
                     sharded.serve(reqs(), max_batch=4), label="reference")
print("PASS")
""")


def test_seq_split_logit_tolerance_empty_shards():
    """Tolerance-lane logit bound with the empty-shard guard on the hot
    path: rows short enough that one rank's kv-sequence shard holds ZERO
    blocks still decode NaN-free, argmax-identical, and within the
    rounding bound of the single-device step."""
    _run(_mesh_header((2,), ("seq",)) + """
from repro.serve.differential import assert_logits_close
from repro.serve.kv_cache import PagedKVCache
m, params = build(CFG)
prompts = [(np.arange(3, dtype=np.int32) * (i + 1)) % cfg_vocab for i in range(4)]
def one_step(use_mesh):
    kv = PagedKVCache(m, max_batch=4, max_seq=32, block_size=8,
                      mesh=mesh if use_mesh else None, prefix_cache=False)
    for i, p in enumerate(prompts):
        assert kv.try_admit(i, p, budget=8) is not None
        _, dense = jax.jit(lambda pr: m.prefill(params, pr, 32))(jnp.asarray(p)[None])
        kv.write_prefill(i, dense)
    step = (m.sharded_paged_step("decode_step_paged", mesh, backend="interpret")
            if use_mesh else m.jit_step("decode_step_paged", backend="interpret"))
    pool, tables, lens = kv.kernel_inputs()
    tok = jnp.asarray([[int(p[-1])] for p in prompts], jnp.int32)
    logits, _ = step(params, pool, tables, lens, tok)
    return np.asarray(logits)
base, got = one_step(False), one_step(True)
# 3-token rows own one block each; the 2-way slot layout places every
# early block on rank 0, so rank 1 is fully empty -> guard on hot path
assert_logits_close(base, got, atol=1e-4, label="seq2 one-step")
print("PASS")
""")


def test_mesh_fallback_warns_once():
    """GQA fallback dedupe: serving repeatedly through a mesh the head
    partitioning cannot divide warns exactly once per (cfg, mesh), keeps
    one deduped ``mesh_fallbacks`` record, and still serves (replicated,
    never wrong tokens)."""
    _run(_header(2) + """
import logging
cfg3 = dataclasses.replace(CFG, n_heads=6, n_kv_heads=3)  # 3 kv-heads ∤ tp=2
m, params = build(cfg3)
eng = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                    attention_backend="interpret")
base = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                     attention_backend="interpret")

class Count(logging.Handler):
    n = 0
    def emit(self, record):
        Count.n += 1

h = Count()
logging.getLogger("repro.serve").addHandler(h)
outs = [eng.serve(reqs(), max_batch=4, mesh=mesh) for _ in range(3)]
logging.getLogger("repro.serve").removeHandler(h)
assert Count.n == 1, f"fallback warned {Count.n} times, want once"
assert len(eng.mesh_fallbacks) == 1, eng.mesh_fallbacks
assert eng.mesh is None  # never adopted the undividable mesh
want = base.serve(reqs(), max_batch=4)
for got in outs:
    for (_, va), (_, vb) in zip(sorted(want.items()), sorted(got.items())):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
print("PASS")
""")


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_preempt_resume_identity(tp):
    """Block-pressure preemption + suffix-resume on the sharded pool:
    evicted-and-resumed requests still match a roomy unsharded serve."""
    _run(_header(tp) + """
m, params = build(CFG)
def workload():
    low = [Request(prompt=np.arange(20, dtype=np.int32) + i, max_new_tokens=10,
                   arrival_time=0.0, priority=0) for i in range(2)]
    high = [Request(prompt=np.arange(9, dtype=np.int32), max_new_tokens=4,
                    arrival_time=0.02, priority=5)]
    return low + high

pressured = ServingEngine(m, params, max_seq=128, kv_layout="paged",
                          max_batch=2, block_size=8, num_blocks=10, mesh=mesh)
roomy = ServingEngine(m, params, max_seq=128, kv_layout="paged",
                      max_batch=4, block_size=8)
p_reqs, r_reqs = workload(), workload()
p_out = pressured.serve(p_reqs)
assert pressured.stats.n_preemptions > 0, "pressure scenario did not evict"
r_out = roomy.serve(r_reqs)
assert roomy.stats.n_preemptions == 0
for a, b in zip(p_reqs, r_reqs):
    np.testing.assert_array_equal(np.asarray(p_out[a.rid]),
                                  np.asarray(r_out[b.rid]))
print("PASS")
""")
