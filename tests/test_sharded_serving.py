"""Sharded-serving differential lane: tensor-parallel paged decode/verify
(DESIGN.md §5) must produce BITWISE the token streams of the single-device
paged path — head partitioning only moves parallel work, never changes a
reduction order. Each test runs in a subprocess with a forced 4-device CPU
host platform so the main pytest process keeps its single real device."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str):
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=ROOT, timeout=600,
    )
    assert r.returncode == 0, f"stderr:\n{r.stderr}\nstdout:\n{r.stdout}"
    assert "PASS" in r.stdout, r.stdout


def _header(tp: int) -> str:
    return f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.model import Model
from repro.serve.engine import ServingEngine
from repro.serve.request import Request
from repro.serve.speculative import SpecConfig
try:
    mesh = jax.make_mesh(({tp},), ("model",), axis_types=(jax.sharding.AxisType.Auto,))
except AttributeError:  # jax 0.4.x: no AxisType
    mesh = jax.make_mesh(({tp},), ("model",))

# 4 kv heads so both 2- and 4-way meshes divide; g=2 exercises GQA grouping
CFG = dataclasses.replace(
    get_config("smollm-135m").reduced(),
    num_layers=2, d_model=64, n_heads=8, n_kv_heads=4, head_dim=8,
)

def build(cfg):
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    return m, params

def reqs(n=4, plen=6, new=8, **kw):
    return [
        Request(prompt=(np.arange(plen, dtype=np.int32) * (i + 1)) % cfg_vocab,
                max_new_tokens=new, **kw)
        for i in range(n)
    ]
cfg_vocab = CFG.vocab_size

def identical(a, b):
    # rids are globally auto-assigned, so match streams by admission order
    assert len(a) == len(b)
    for (_, va), (_, vb) in zip(sorted(a.items()), sorted(b.items())):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
"""


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_token_identity_modes(tp):
    """plain / speculative K∈{2,4} / chunked-prefill serving over a
    tp-way head-partitioned pool == the single-device paged path,
    bitwise, through the interpret (real kernel code) backend."""
    _run(_header(tp) + """
m, params = build(CFG)
base = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                     attention_backend="interpret")
sharded = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                        attention_backend="kernel", mesh=mesh)
assert sharded.mesh is mesh
assert sharded.attention_backend == "interpret"  # mesh-aware resolution

# the pool really is head-partitioned over the mesh
sched = sharded.scheduler(4)
spec = sched.kv.pool["k"].sharding.spec
assert "model" in tuple(spec), spec

identical(base.serve(reqs(), max_batch=4), sharded.serve(reqs(), max_batch=4))
for K in (2, 4):
    identical(base.serve(reqs(), max_batch=4, spec=SpecConfig(k=K)),
              sharded.serve(reqs(), max_batch=4, spec=SpecConfig(k=K)))
identical(base.serve(reqs(plen=12), max_batch=4, chunk_size=4),
          sharded.serve(reqs(plen=12), max_batch=4, chunk_size=4))
print("PASS")
""")


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_token_identity_int8_kv(tp):
    """int8-KV pool (values + per-vector scales both head-partitioned)
    decodes and verifies bitwise-identically to single-device int8."""
    _run(_header(tp) + """
cfg8 = dataclasses.replace(CFG, kv_quant=True)
m, params = build(cfg8)
base = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                     attention_backend="interpret")
sharded = ServingEngine(m, params, max_seq=64, kv_layout="paged",
                        attention_backend="interpret", mesh=mesh)
identical(base.serve(reqs(), max_batch=4), sharded.serve(reqs(), max_batch=4))
identical(base.serve(reqs(), max_batch=4, spec=SpecConfig(k=2)),
          sharded.serve(reqs(), max_batch=4, spec=SpecConfig(k=2)))
print("PASS")
""")


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_preempt_resume_identity(tp):
    """Block-pressure preemption + suffix-resume on the sharded pool:
    evicted-and-resumed requests still match a roomy unsharded serve."""
    _run(_header(tp) + """
m, params = build(CFG)
def workload():
    low = [Request(prompt=np.arange(20, dtype=np.int32) + i, max_new_tokens=10,
                   arrival_time=0.0, priority=0) for i in range(2)]
    high = [Request(prompt=np.arange(9, dtype=np.int32), max_new_tokens=4,
                    arrival_time=0.02, priority=5)]
    return low + high

pressured = ServingEngine(m, params, max_seq=128, kv_layout="paged",
                          max_batch=2, block_size=8, num_blocks=10, mesh=mesh)
roomy = ServingEngine(m, params, max_seq=128, kv_layout="paged",
                      max_batch=4, block_size=8)
p_reqs, r_reqs = workload(), workload()
p_out = pressured.serve(p_reqs)
assert pressured.stats.n_preemptions > 0, "pressure scenario did not evict"
r_out = roomy.serve(r_reqs)
assert roomy.stats.n_preemptions == 0
for a, b in zip(p_reqs, r_reqs):
    np.testing.assert_array_equal(np.asarray(p_out[a.rid]),
                                  np.asarray(r_out[b.rid]))
print("PASS")
""")
