"""Aira pipeline behaviour: spec stages, deps, gate, Relic examples,
granularity bands, and the paper's §VII accept/reject pattern."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Aira, Microtask, OverlapModel, Region, Workload, relic_pfor
from repro.core.deps import MemoryTrace, check_conflicts, static_deps
from repro.core.overlap_model import CPU_HW, OPENMP, RELIC, gate
from repro.core.spec import AIRA_SPEC, RELIC_EXAMPLES


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("ex", RELIC_EXAMPLES, ids=lambda e: e["name"])
def test_relic_examples_match_vmap(ex):
    items = ex["items"]()
    want = jax.vmap(ex["fn"])(items)
    got = relic_pfor(ex["fn"], items, granularity=4)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-5
        ),
        want,
        got,
    )


@pytest.mark.parametrize("n,g", [(7, 3), (16, 5), (100, 8), (33, 33)])
def test_relic_pfor_ragged(n, g):
    fn = lambda x: 2.0 * x + 1.0
    xs = jnp.arange(n, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(relic_pfor(fn, xs, granularity=g)), np.asarray(jax.vmap(fn)(xs))
    )


# ---------------------------------------------------------------------------
def test_static_deps_private_vs_shared_scatter():
    table = jnp.zeros((32,))

    def private(x):  # scatter into a locally-created buffer
        buf = jnp.zeros((8,)).at[0].set(x.sum())
        return buf.sum()

    def shared(idx):  # scatter into closure-captured (shared) state
        return table.at[idx].add(1.0).sum()

    rp = static_deps(private, jnp.ones((4,)))
    rs = static_deps(shared, jnp.int32(3))
    assert rp.trivially_parallel
    assert not rs.trivially_parallel


def test_dynamic_conflict_detection():
    # two tasks write the same address → conflict
    t = MemoryTrace(reads=[[1], [2]], writes=[[5], [5]])
    conflict, why = check_conflicts(t, 2)
    assert conflict
    t2 = MemoryTrace(reads=[[1, 5], [2, 6]], writes=[[10], [11]])
    conflict, _ = check_conflicts(t2, 2)
    assert not conflict


# ---------------------------------------------------------------------------
def test_overlap_model_invariants():
    m = OverlapModel(CPU_HW)
    t = Microtask(flops=500, bytes=2048, chain=8, vector=True)
    p = m.predict(t, 1000)
    assert p.serial > 0 and p.smt2 > 0 and p.smp2 > 0
    # smt2 cannot beat the shared-bandwidth floor
    assert p.smt2 >= 1000 * 2048 / CPU_HW.hbm_bw
    # relic dispatch is cheaper than openmp at every granularity
    p_omp = m.predict(t, 1000, runtime=OPENMP)
    assert p_omp.smt2 >= p.smt2


def test_compute_bound_smt_gain_matches_paper_anchor():
    """PFL anchor (paper Fig. 1): ≈ +5% for a compute-bound kernel at
    1000 items — the ILP-slack gain net of contention."""
    from repro.bench_suite import pfl

    m = OverlapModel(CPU_HW)
    t0 = pfl.microtask()
    g = 250
    t = Microtask(t0.flops * g, t0.bytes * g, 0, True)
    p = m.predict(t, 1000 // g)
    assert 0.01 < p.gain("smt2") < 0.10


def test_gate_thresholds():
    m = OverlapModel(CPU_HW)
    good = m.predict(Microtask(flops=100, bytes=512, chain=16, vector=True), 4096)
    ok, _ = gate(good)
    assert ok
    bad = m.predict(Microtask(flops=10, bytes=4096, chain=0, vector=True), 64)
    ok, why = gate(bad)
    assert not ok and "rejected" in why


def test_spec_has_all_stages():
    names = [s.name for s in AIRA_SPEC]
    assert names == [
        "profile", "annotate", "static_deps", "dynamic_deps", "simulate", "restructure",
    ]


# ---------------------------------------------------------------------------
def test_paper_section7_pattern():
    """7/10 positive, Fraud gate-rejected, 1-Hop/BVH forced-negative,
    geomeans within tolerance of the paper's 25.2% / 17%."""
    from benchmarks import fig34_aira

    rows, gm_pos, gm_all = fig34_aira.run(print_fn=lambda *_: None, timing=False)
    by = {r["name"]: r for r in rows}
    assert not by["Fraud"]["accepted"]
    assert by["1-Hop"]["realized"] < 0
    assert by["BVH"]["realized"] < -0.4
    positives = [r for r in rows if r["realized"] > 0]
    assert len(positives) == 7
    assert 0.18 <= gm_pos <= 0.35  # paper: 25.2%
    assert 0.10 <= gm_all <= 0.25  # paper: 17%
    # predicted-vs-realized sign gate: the Fig.4 forced rows are accepted
    # AND flagged regressed; winners and gate-rejects are not
    for forced in ("1-Hop", "BVH"):
        assert by[forced]["accepted"] and by[forced]["regressed"]
    assert not by["Fraud"]["regressed"]
    for r in positives:
        assert not r["regressed"], r["name"]


def test_flag_regressions_sign_gate():
    """``flag_regressions`` marks exactly the accept-on-positive-
    prediction / realized-negative rows, in place, touching nothing
    else about the row."""
    from benchmarks.fig34_aira import flag_regressions

    rows = [
        dict(name="win", accepted=True, predicted=0.25, realized=0.25),
        dict(name="forced", accepted=True, predicted=0.09, realized=-0.05),
        dict(name="rejected", accepted=False, predicted=-0.02, realized=0.0),
        dict(name="flat", accepted=True, predicted=0.0, realized=0.0),
    ]
    out = flag_regressions(rows)
    assert out is rows  # in place, chainable
    assert [r["regressed"] for r in rows] == [False, True, False, False]
    assert rows[1]["accepted"], "the flag must not demote the gate decision"


def test_adviser_rejects_without_trace_for_shared_writes():
    table = jnp.zeros((64,))

    def fn(i):
        return table.at[i].add(1.0).sum()

    items = jnp.arange(32, dtype=jnp.int32)
    region = Region("shared", fn, items, task_flops=64, task_bytes=512, task_chain=4)
    rep = Aira(hw=CPU_HW).advise(Workload("w", lambda: None, [region]))
    assert not rep.decisions[0].accepted
    assert any("no trace" in s for s in rep.decisions[0].stage_log)
