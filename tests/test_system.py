"""End-to-end behaviour tests for the paper's system: the full Aira flow
(profile → annotate → deps → simulate → restructure) on a real workload,
plus end-to-end train + serve round trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.bench_suite import BENCHMARKS
from repro.core import Aira, profile_step
from repro.core.overlap_model import CPU_HW


def test_aira_end_to_end_geospatial():
    """Full pipeline on GeoSpatial: accepted, restructured, semantics
    preserved, report readable."""
    from benchmarks.fig34_aira import make_workload

    b = BENCHMARKS["GeoSpatial"]
    data = b.build()
    wl = make_workload(b, data)
    report = Aira(hw=CPU_HW).advise(wl)
    d = report.decisions[0]
    assert d.accepted
    assert d.schedule.strategy == "smt2"
    # the restructured callable computes the same result (the benchmark
    # declares combine="sum", honored by the plan layer)
    got = np.asarray(d.parallel_fn(), np.float32)
    want = np.asarray(jax.vmap(b.item_fn(data))(b.items(data)).sum(0), np.float32)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-5)
    # per-item (stack) semantics remain available through the same layer
    stacked = np.asarray(b.parallel_value(data, granularity=d.schedule.granularity))
    np.testing.assert_allclose(
        stacked,
        np.asarray(jax.vmap(b.item_fn(data))(b.items(data))),
        atol=1e-4,
    )
    text = report.render()
    assert "Parallelize this program with Aira" in text
    assert "static:" in d.summary() and "simulate:" in d.summary()


def test_profile_step_roofline_terms():
    ps = profile_step(
        lambda x, w: jnp.tanh(x @ w),
        jax.ShapeDtypeStruct((512, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 512), jnp.float32),
        name="mm",
    )
    assert ps.flops > 2 * 512**3 * 0.99
    assert ps.terms.dominant in ("compute", "memory")
    rep = ps.report()
    assert "roofline" in rep and "hotspots" in rep


def test_train_then_serve_roundtrip():
    """Train a reduced model a few steps, then serve greedily — the whole
    example-application path in miniature."""
    from repro.configs import get_config
    from repro.data import SyntheticLMData
    from repro.models import Model
    from repro.serve import ServingEngine
    from repro.train import AdamW, make_train_step

    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=20)
    state = opt.init(params)
    data = SyntheticLMData(cfg, batch=4, seq=32)
    step = jax.jit(make_train_step(m, opt))
    losses = []
    for i in range(8):
        params, state, metrics = step(params, state, data.batch_at(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]  # learning happens

    eng = ServingEngine(m, params, max_seq=64)
    out = eng.generate(jnp.ones((2, 8), jnp.int32), n_steps=4)
    assert out.shape == (2, 4)
    assert eng.stats.percentile(50) > 0
