"""Online adaptive adviser (serve/controller.py, DESIGN.md §9): the
shared pricing functions against the offline advisor tools they were
refactored from, OnlineAdviser hysteresis unit behaviour (switch on a
priced win, threshold and dwell gates, K=0 probing with revert),
admission throttling, retrace-free live switching (randomized mid-serve
K/backend decisions → zero new jit compiles after ``prime()``), token
identity under any decision sequence (a pinned controller == the static
configuration, bitwise), the ModelDraftSource 0→K catch-up, controller
observability surfaces (Prometheus text, registry snapshot,
``serving_summary()["controller"]``), and ``window_summary`` cold-start
finiteness."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs import get_config
from repro.core.tools import (
    KernelAdvisorTool,
    SpecMeasurement,
    SpeculationAdvisorTool,
    price_backends,
    price_speculation,
)
from repro.models import Model
from repro.serve import (
    Decision,
    OnlineAdviser,
    PinnedController,
    Request,
    ServingEngine,
    SpecConfig,
)
from repro.serve.telemetry import MetricsRegistry

_STATE: dict = {}


def _model_state():
    """Lazy module singleton (not a fixture: the hypothesis stub calls
    property tests with drawn args only, so they can't take fixtures).
    The engine is primed over the K × backend grid once — every test
    that switches mid-serve rides the same warmed trace families."""
    if not _STATE:
        cfg = get_config("smollm-135m").reduced()
        m = Model(cfg)
        params, _ = m.init(jax.random.key(0))
        eng = ServingEngine(m, params, max_seq=64, kv_layout="paged", block_size=8)
        primed = eng.prime(2, ks=(0, 2, 4), backends=("reference", "interpret"))
        _STATE["v"] = (cfg, m, params, eng, primed)
    return _STATE["v"]


@pytest.fixture(scope="module")
def served():
    return _model_state()


def _workload(vocab, specs=((8, 6), (12, 8), (8, 5), (16, 4)), arrival=0.0, seed=7):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(0, vocab, size=n).astype(np.int32),
            max_new_tokens=t, arrival_time=arrival * i,
        )
        for i, (n, t) in enumerate(specs)
    ]


def _jit_cache_size(eng) -> int:
    fns = [eng._prefill, eng._prefill_prefix]
    for family in eng._steps.values():
        fns.extend(family.values())
    return sum(
        f._cache_size() for f in fns if f is not None and hasattr(f, "_cache_size")
    )


# ---------------------------------------------------------------------------
# shared pricing == the offline advisor tools (the refactor changed nothing)


def test_price_speculation_matches_tool():
    tool = SpeculationAdvisorTool(ks=(0, 2, 4, 8))
    for p in (0.0, 0.3, 0.6, 0.9, 1.0):
        for draft in (0.01, 0.1, 1.0):
            for v8 in (2.2, 4.0, 9.0):
                m = SpecMeasurement(
                    draft_ms_per_token=draft,
                    verify_ms={0: 2.0, 8: v8},
                    acceptance_rate=p,
                )
                k_tool, gain_tool, _ = tool.choose(m)
                k, cost, gain, costs = price_speculation(m, (0, 2, 4, 8))
                assert k == k_tool, (p, draft, v8)
                assert gain == pytest.approx(gain_tool)
                assert costs[0] == pytest.approx(m.verify_cost(0))
                if k:
                    assert cost == pytest.approx(costs[k])


def test_price_speculation_threshold_gates_to_zero():
    m = SpecMeasurement(0.05, {0: 2.0, 8: 2.2}, 0.05)  # marginal win at best
    k, cost, gain, _ = price_speculation(m, (0, 2, 4, 8), threshold=0.5)
    assert k == 0 and gain == 0.0 and cost == pytest.approx(m.verify_cost(0))


def test_price_backends_matches_tool():
    tool = KernelAdvisorTool()
    for cells in (
        {"reference": 2.0, "kernel": 1.0},
        {"reference": 1.0, "kernel": 2.0},
        {"reference": 1.0, "kernel": 0.99},  # under the 2% gate
    ):
        from repro.core.tools import KernelMeasurement

        b_tool, gain_tool, _ = tool.choose(
            KernelMeasurement.make("llama", "paged", 2, dict(cells))
        )
        b, ms, gain = price_backends(dict(cells))
        assert b == b_tool and gain == pytest.approx(gain_tool)
        assert ms == pytest.approx(cells[b])
    # online baseline: priced against the live arm, not "reference"
    b, _, gain = price_backends(
        {"reference": 1.0, "kernel": 1.5}, baseline="kernel"
    )
    assert b == "reference" and gain == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# OnlineAdviser unit behaviour over synthetic sensor windows


def _summary(**kw):
    base = dict(
        window=8, ticks=8, acceptance_rate=0.0, proposed=0.0, accepted=0.0,
        spec_steps=0.0, p50_draft_ms=0.0, p50_verify_ms=0.0, queue_depth=0.0,
        active=2.0, pool_occupancy=0.5, pool_free_blocks=10.0,
        step_cost_ms=0.0, p99_step_ms=0.0, admitted=0.0, preemptions=0.0,
        rejected=0.0, prefix_hit_rate=0.0, chunk_utilization=0.0,
        alloc_rate=0.0, evict_rate=0.0, park_rate=0.0, retraces=0.0,
    )
    base.update(kw)
    return base


def _seeded(**kw):
    args = dict(ks=(0, 2, 4), decision_interval=1, window=8, dwell=2,
                threshold=0.05, probe_every=2)
    args.update(kw)
    ctl = OnlineAdviser(**args)
    # K=0 decode 2ms; verify widths barely above it — a high p̂ pays off
    ctl.seed_costs({"reference": {0: 2.0, 2: 2.3, 4: 2.6}},
                   draft_ms_per_token=0.05)
    return ctl


def test_switch_up_on_observed_acceptance():
    ctl = _seeded()
    d = ctl.decide(
        _summary(acceptance_rate=0.9, proposed=8.0, accepted=7.2,
                 p50_draft_ms=0.1, p50_verify_ms=2.3),
        k_live=2, backend_live="reference", step=1,
    )
    assert d.k == 4 and d.switched and d.predicted_gain > 0.05
    assert ctl.n_switches == 1 and ctl.dwell_remaining == 2


def test_dwell_blocks_immediate_reswitch():
    ctl = _seeded()
    ctl.decide(_summary(acceptance_rate=0.9, proposed=8.0, p50_verify_ms=2.3),
               k_live=2, backend_live="reference", step=1)
    assert ctl.dwell_remaining == 2
    # the very next window prices a flip back — dwell holds the arm
    d = ctl.decide(_summary(acceptance_rate=0.0, proposed=8.0, p50_verify_ms=9.0),
                   k_live=4, backend_live="reference", step=2)
    assert d.k == 4 and not d.switched
    d = ctl.decide(_summary(acceptance_rate=0.0, proposed=8.0, p50_verify_ms=9.0),
                   k_live=4, backend_live="reference", step=3)
    assert d.k == 4 and not d.switched
    # dwell spent: the down-switch lands
    d = ctl.decide(_summary(acceptance_rate=0.0, proposed=8.0, p50_verify_ms=9.0),
                   k_live=4, backend_live="reference", step=4)
    assert d.k == 0 and d.switched


def test_threshold_blocks_marginal_switch():
    ctl = _seeded(threshold=10.0, initial_k=2)  # nothing clears a 1000% gate
    d = ctl.decide(
        _summary(acceptance_rate=0.9, proposed=8.0, p50_verify_ms=2.3),
        k_live=2, backend_live="reference", step=1,
    )
    assert d.k == 2 and not d.switched and ctl.n_switches == 0


def test_probe_fires_at_k0_and_reverts_without_win():
    ctl = OnlineAdviser(ks=(0, 2, 4), decision_interval=1, window=8, dwell=0,
                        threshold=0.05, probe_every=2)
    ctl.seed_costs({"reference": {0: 2.0, 2: 4.0, 4: 8.0}})  # spec never pays
    # no observation yet → immediate probe at the smallest positive K
    d = ctl.decide(_summary(step_cost_ms=2.0), k_live=0,
                   backend_live="reference", step=1)
    assert d.probe and d.k == 2 and not d.switched
    # the probe window shows poor acceptance → revert to the committed 0
    d = ctl.decide(_summary(acceptance_rate=0.1, proposed=4.0, accepted=0.4,
                            p50_draft_ms=0.2, p50_verify_ms=4.0),
                   k_live=2, backend_live="reference", step=2)
    assert d.k == 0 and not d.probe and not d.switched
    assert "probe over" in d.reason
    # staleness accumulates again → next probe after probe_every decisions
    d3 = ctl.decide(_summary(step_cost_ms=2.0), k_live=0,
                    backend_live="reference", step=3)
    d4 = ctl.decide(_summary(step_cost_ms=2.0), k_live=0,
                    backend_live="reference", step=4)
    assert not d3.probe and d4.probe


def test_probe_commits_on_priced_win_and_counts_switch():
    ctl = _seeded(dwell=0)
    d = ctl.decide(_summary(step_cost_ms=2.0), k_live=0,
                   backend_live="reference", step=1)
    assert d.probe and d.k == 2
    # probe observed near-perfect acceptance: pricing lifts K and the
    # commit counts as ONE switch against the committed arm (0)
    d = ctl.decide(_summary(acceptance_rate=0.95, proposed=4.0, accepted=3.8,
                            p50_draft_ms=0.1, p50_verify_ms=2.3),
                   k_live=2, backend_live="reference", step=2)
    assert d.k == 4 and d.switched and ctl.n_switches == 1


def test_admission_throttle_under_pressure():
    ctl = _seeded()
    d = ctl.decide(
        _summary(preemptions=2.0, pool_occupancy=0.95, step_cost_ms=2.0),
        k_live=0, backend_live="reference", step=1,
    )
    assert d.admit_budget == 1
    d = ctl.decide(
        _summary(preemptions=0.0, pool_occupancy=0.95, step_cost_ms=2.0),
        k_live=0, backend_live="reference", step=2,
    )
    assert d.admit_budget is None


def test_audit_trail_json_ready():
    import json

    ctl = _seeded()
    ctl.decide(_summary(acceptance_rate=0.9, proposed=8.0, p50_verify_ms=2.3),
               k_live=2, backend_live="reference", step=1)
    trail = ctl.audit_trail()
    assert len(trail) == 1 and trail[0]["k"] == 4
    json.dumps(trail)
    json.dumps(ctl.summary())


def test_bad_construction_rejected():
    with pytest.raises(ValueError, match="initial_k"):
        OnlineAdviser(ks=(0, 2), initial_k=3)
    with pytest.raises(ValueError, match=">= 0"):
        OnlineAdviser(ks=(-1, 2))


# ---------------------------------------------------------------------------
# retrace-free switching + token identity through a real engine


def test_pinned_controller_matches_static_bitwise(served):
    cfg, _, _, eng, _ = _model_state()
    static = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                       spec=SpecConfig(k=2, drafter="ngram"))
    pinned = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                       spec=SpecConfig(k=2, drafter="ngram"),
                       controller=PinnedController(2, decision_interval=2))
    for rid_a, rid_b in zip(sorted(static), sorted(pinned)):
        np.testing.assert_array_equal(np.asarray(static[rid_a]),
                                      np.asarray(pinned[rid_b]))
    # the pinned run surfaced controller state; decisions were taken
    s = eng.stats.serving_summary()
    assert s["controller"]["k"] == 2 and s["controller"]["decisions"] > 0


class ScriptedController:
    """Duck-typed controller replaying a fixed (k, backend) script —
    the randomized-switching harness (arbitrary mid-serve decisions,
    none of them pricing-driven)."""

    def __init__(self, script, ks=(0, 2, 4), backends=None, interval=2):
        self.script = list(script)
        self.ks = tuple(ks)
        self.backends = backends
        self.decision_interval = int(interval)
        self.window = 8
        self.initial_k = 0
        self.decisions: list = []
        self.n_switches = 0
        self.dwell_remaining = 0
        self._i = 0

    def decide(self, summary, *, k_live, backend_live, step):
        k, backend = self.script[self._i % len(self.script)]
        self._i += 1
        d = Decision(step=step, k=k, backend=backend or backend_live,
                     switched=(k != k_live), reason="scripted")
        self.n_switches += int(d.switched)
        self.decisions.append(d)
        return d


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_switching_no_retrace_and_token_identity(seed):
    cfg, _, _, eng, _ = _model_state()
    rng = np.random.default_rng(seed)
    script = [
        (int(rng.choice([0, 2, 4])), str(rng.choice(["reference", "interpret"])))
        for _ in range(8)
    ]
    reqs = _workload(cfg.vocab_size, seed=seed)
    base = eng.serve(list(reqs), max_batch=2, seed=0,
                     spec=SpecConfig(k=4, drafter="ngram"))
    size0 = _jit_cache_size(eng)
    ctl = ScriptedController(script, backends=("reference", "interpret"))
    out = eng.serve(_workload(cfg.vocab_size, seed=seed), max_batch=2, seed=0,
                    spec=SpecConfig(k=4, drafter="ngram"), controller=ctl)
    # greedy streams are invariant under ANY live decision sequence
    for rid_a, rid_b in zip(sorted(base), sorted(out)):
        np.testing.assert_array_equal(np.asarray(base[rid_a]),
                                      np.asarray(out[rid_b]))
    # every switch was a cache hit in the primed K × backend grid
    assert _jit_cache_size(eng) == size0
    assert eng.stats.registry.counter("engine.retraces").value == 0.0
    assert len(ctl.decisions) > 0


def test_model_drafter_zero_to_k_catchup(served):
    """0→K transitions with a stateful drafter re-sync the draft cache
    from the committed history (rows that decoded plain while K was 0
    advanced the target cache only) — tokens stay bitwise identical."""
    cfg, m, params, _, _ = _model_state()
    eng = ServingEngine(m, params, max_seq=64, kv_layout="slot")
    spec = SpecConfig(k=2, drafter="model", draft_model=m, draft_params=params)
    base = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                     spec=SpecConfig(k=0))
    # flip 0 → 2 → 0 → 2 every other decision, mid-generation
    ctl = ScriptedController([(0, None), (2, None)] * 4, ks=(0, 2), interval=2)
    out = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                    spec=spec, controller=ctl)
    for rid_a, rid_b in zip(sorted(base), sorted(out)):
        np.testing.assert_array_equal(np.asarray(base[rid_a]),
                                      np.asarray(out[rid_b]))


def test_online_adviser_end_to_end_with_seeded_costs(served):
    cfg, _, _, eng, primed = _model_state()
    ctl = OnlineAdviser(ks=(0, 2, 4), decision_interval=2, window=6,
                        dwell=1, threshold=0.05, probe_every=2)
    ctl.seed_costs(primed)
    # long budgets on short prompts: self-repetitive → draftable
    reqs = _workload(cfg.vocab_size, specs=((6, 16), (8, 16), (6, 12)))
    base = eng.serve(_workload(cfg.vocab_size, specs=((6, 16), (8, 16), (6, 12))),
                     max_batch=2, seed=0, spec=SpecConfig(k=0))
    out = eng.serve(reqs, max_batch=2, seed=0,
                    spec=SpecConfig(k=4, drafter="ngram"), controller=ctl)
    for rid_a, rid_b in zip(sorted(base), sorted(out)):
        np.testing.assert_array_equal(np.asarray(base[rid_a]),
                                      np.asarray(out[rid_b]))
    assert len(ctl.decisions) > 0
    trail = ctl.audit_trail()
    assert all(d["inputs"]["window"] >= 0 for d in trail)


def test_admit_budget_applied(served):
    cfg, _, _, eng, _ = _model_state()

    class Throttler(PinnedController):
        def decide(self, summary, *, k_live, backend_live, step):
            d = super().decide(summary, k_live=k_live,
                               backend_live=backend_live, step=step)
            d.admit_budget = 1
            return d

    ctl = Throttler(0, decision_interval=1)
    base = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                     spec=SpecConfig(k=0))
    out = eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
                    spec=SpecConfig(k=0), controller=ctl)
    for rid_a, rid_b in zip(sorted(base), sorted(out)):
        np.testing.assert_array_equal(np.asarray(base[rid_a]),
                                      np.asarray(out[rid_b]))
    assert eng.stats.serving_summary()["controller"]["admit_budget"] == 1


# ---------------------------------------------------------------------------
# observability surfaces


def test_controller_metrics_in_prometheus_and_snapshot(served):
    cfg, _, _, eng, _ = _model_state()
    eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
              spec=SpecConfig(k=2, drafter="ngram"),
              controller=PinnedController(2, decision_interval=2))
    reg = eng.stats.registry
    assert reg.counter("controller.decisions").value > 0
    snap = reg.snapshot()
    assert "controller.k" in snap["gauges"]
    assert "controller.dwell_remaining" in snap["gauges"]
    assert "controller.backend_index" in snap["gauges"]
    text = reg.prometheus_text()
    assert "# TYPE controller_decisions counter" in text
    assert "controller_k" in text
    # a controller-less serve carries no controller key in the summary
    eng.serve(_workload(cfg.vocab_size), max_batch=2, seed=0,
              spec=SpecConfig(k=0))
    assert "controller" not in eng.stats.serving_summary()


# ---------------------------------------------------------------------------
# window_summary cold start: every sensor is finite from tick zero


def test_window_summary_cold_start_finite():
    import math

    reg = MetricsRegistry()
    for n in (1, 4, 64):
        s = reg.window_summary(n)
        for key, v in s.items():
            assert v is not None, key
            if isinstance(v, float):
                assert math.isfinite(v), (key, v)
        assert s["acceptance_rate"] == 0.0
        assert s["p50_draft_ms"] == 0.0 and s["p50_verify_ms"] == 0.0
        assert s["spec_steps"] == 0.0
        assert s["window"] == 0
    # one tick with zero denominators stays finite too
    reg.counter("serve.spec_proposed")
    reg.counter("serve.spec_accepted")
    reg.tick()
    s = reg.window_summary(4)
    assert s["window"] == 1 and s["acceptance_rate"] == 0.0
    # partial window: fewer ticks than n is well-defined
    reg.series("serve.step_ms").append(2.0)
    reg.tick()
    s = reg.window_summary(64)
    assert s["window"] == 2 and s["step_cost_ms"] == pytest.approx(2.0)
