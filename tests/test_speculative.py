"""Speculative decoding subsystem: verify-step semantics, per-row
rollback (slot lengths, paged tail blocks), greedy token-identity of
speculative serve vs plain greedy across both drafters × both KV
layouts × K ∈ {2, 4, 8} on randomized open-loop traces (EOS early
finish, eviction under block pressure), and the SpeculationAdvisorTool
gate that picks the depth."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.tools import (
    SpecMeasurement,
    SpeculationAdvisorTool,
    expected_tokens_per_round,
)
from repro.models import Model
from repro.serve import (
    ModelDraftSource,
    NGramDraftSource,
    PagedKVCache,
    Request,
    ServingEngine,
    SlotKVCache,
    SpecConfig,
    advise_depth,
)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1), (5, 16), 0, cfg.vocab_size)
    return cfg, m, params, prompts


@pytest.fixture(scope="module")
def draft(served):
    """A 1-layer draft model sharing the target's tokenizer space."""
    cfg, _, _, _ = served
    dcfg = dataclasses.replace(cfg, num_layers=1, name="draft-smoke")
    dm = Model(dcfg)
    dparams, _ = dm.init(jax.random.key(7))
    return dm, dparams


def _trace(prompts, lens, budgets, eos=None, eos_req=None):
    return [
        Request(
            prompt=np.asarray(prompts[i, : lens[i]]),
            max_new_tokens=int(budgets[i]),
            arrival_time=0.01 * i,
            eos_id=eos if i == eos_req else None,
        )
        for i in range(len(lens))
    ]


# ---------------------------------------------------------------------------
# verify step semantics


def test_verify_step_reproduces_sequential_decode(served):
    """One fixed-K verify forward over the greedy stream returns, at
    every position, the argmax the sequential decode would produce —
    the invariant greedy-equivalence acceptance rests on."""
    _, m, params, prompts = served
    logits0, cache = jax.jit(lambda p, t: m.prefill(p, t, 32))(params, prompts[:2, :8])
    # roll the greedy stream with plain decode steps
    decode = jax.jit(m.decode_step)
    toks = [jnp.argmax(logits0, axis=-1)]
    dcache = cache
    for _ in range(4):
        lg, dcache = decode(params, dcache, toks[-1][:, None])
        toks.append(jnp.argmax(lg, axis=-1))
    stream = jnp.stack(toks, axis=1)  # [B, 5]: tok0 .. tok4
    # one verify over [tok0..tok3] must predict [tok1..tok4]
    vlogits, vcache = jax.jit(m.verify_step)(params, cache, stream[:, :4])
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(vlogits, axis=-1)), np.asarray(stream[:, 1:5])
    )
    np.testing.assert_array_equal(
        np.asarray(vcache["len"]), np.asarray(cache["len"]) + 4
    )


def test_verify_step_rejects_unrewindable_families():
    ssm = Model(get_config("mamba2-370m").reduced())
    with pytest.raises(ValueError, match="greedy-equivalent"):
        ssm.verify_step(None, None, None)
    moe = Model(get_config("granite-moe-1b-a400m").reduced())
    with pytest.raises(ValueError, match="greedy-equivalent"):
        moe.verify_step(None, None, None)


# ---------------------------------------------------------------------------
# rollback


def test_slot_truncate_row_rewinds_length(served):
    _, m, _, _ = served
    kv = SlotKVCache(m, max_batch=2, max_seq=16)
    slot = kv.alloc(0)
    kv.cache["len"] = kv.cache["len"].at[slot].set(9)
    kv.truncate_row(slot, 3)
    assert int(kv.cache["len"][slot]) == 6
    kv.truncate_rows(np.array([2, 5]))  # dead row clamps at zero
    assert int(kv.cache["len"][slot]) == 4
    assert int(kv.cache["len"][1 - slot]) == 0
    kv.free(slot)
    with pytest.raises(RuntimeError, match="truncate of free slot"):
        kv.truncate_row(slot, 1)


def test_paged_truncate_row_releases_tail_blocks(served):
    """A verify's rejected tail releases its claimed blocks back to the
    pool with the reservation restored; shared prefix blocks stay."""
    _, m, _, _ = served
    kv = PagedKVCache(m, max_batch=2, max_seq=32, block_size=4)
    row, _ = kv.try_admit(0, tuple(range(8)), budget=12)
    free0 = kv.allocator.n_free
    out0 = kv._row_outstanding[row]
    kv.ensure_tail_n(row, 5)  # positions 8..12 → claims 2 tail blocks
    assert kv.allocator.n_free == free0 - 2
    kv.advance_n(row, 5)
    kv.truncate_row(row, 4)  # keep 1 of the 5: back into block 2
    assert int(kv.cache_len[row]) == 9
    assert kv.allocator.n_free == free0 - 1  # one tail block released
    assert kv._row_outstanding[row] == out0 - 1
    kv.check_invariants()
    # a second request aliasing the prompt prefix: its rollback can
    # never release the shared blocks (they sit below the prompt)
    row2, hits = kv.try_admit(1, tuple(range(8)) + (99,), budget=4)
    assert len(hits) == 2
    kv.ensure_tail_n(row2, 3)
    kv.advance_n(row2, 3)
    kv.truncate_row(row2, 3)
    assert all(kv.allocator.refcount[b] == 2 for b in hits)
    kv.check_invariants()
    kv.free_row(row2)
    with pytest.raises(RuntimeError, match="truncate of free row"):
        kv.truncate_row(row2, 1)


# ---------------------------------------------------------------------------
# differential: speculative serve == plain greedy serve, token for token


def _baselines(m, params, prompts, lens, budgets):
    eng = ServingEngine(m, params, max_seq=64)
    bases = [
        np.asarray(
            eng.generate(prompts[i : i + 1, : lens[i]], n_steps=int(budgets[i]))[0]
        )
        for i in range(len(lens))
    ]
    return bases


@pytest.mark.parametrize("kv_layout", ["slot", "paged"])
@pytest.mark.parametrize("drafter", ["ngram", "model"])
def test_speculative_serve_token_identical_to_greedy(served, draft, kv_layout, drafter):
    """Both drafters × both KV layouts × K ∈ {2,4,8}: a randomized
    open-loop trace (staggered arrivals, divergent prompt lengths and
    budgets, one EOS early finish) decodes token-for-token identical to
    the plain greedy baseline, with the KV invariants intact."""
    _, m, params, prompts = served
    dm, dparams = draft
    rng = np.random.default_rng(1)
    n = 4
    lens = rng.integers(3, 16, size=n)
    budgets = rng.integers(2, 8, size=n)
    bases = _baselines(m, params, prompts, lens, budgets)
    eos = int(bases[0][min(1, int(budgets[0]) - 1)])
    cut = int(np.argmax(bases[0] == eos))
    expected = [b if i != 0 else b[: cut + 1] for i, b in enumerate(bases)]

    spec_kw = (
        dict(drafter="ngram")
        if drafter == "ngram"
        else dict(drafter="model", draft_model=dm, draft_params=dparams)
    )
    eng = ServingEngine(m, params, max_seq=64, kv_layout=kv_layout, block_size=4)
    for k in (2, 4, 8):
        reqs = _trace(prompts, lens, budgets, eos, 0)
        sched = eng.scheduler(3, spec=SpecConfig(k=k, **spec_kw))
        out = sched.run(reqs)
        sched.kv.check_invariants()
        for i, req in enumerate(reqs):
            np.testing.assert_array_equal(
                out[req.rid], expected[i], err_msg=f"K={k} req {i}"
            )
            assert req.finished
        s = eng.stats.serving_summary()["speculative"]
        assert s["k"] == k and s["proposed"] > 0
        assert 0.0 <= s["acceptance_rate"] <= 1.0


def test_speculative_paged_eviction_under_pressure(served):
    """Speculation on a block-starved paged pool: margin reservations,
    lazy tail claims, rollback releases, and LRU eviction of cached
    prompt blocks all interleave — outputs still match the baselines."""
    _, m, params, prompts = served
    lens, budgets = (12, 8, 14), (4, 6, 3)
    bases = [
        np.asarray(
            ServingEngine(m, params, max_seq=64).generate(
                prompts[i : i + 1, : lens[i]], n_steps=budgets[i]
            )[0]
        )
        for i in range(3)
    ]
    eng = ServingEngine(
        m, params, max_seq=64, kv_layout="paged", block_size=4, num_blocks=8
    )
    reqs = _trace(prompts, lens, budgets)
    sched = eng.scheduler(2, spec=SpecConfig(k=4))
    out = sched.run(reqs)
    sched.kv.check_invariants()
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(out[r.rid], bases[i])


def test_self_draft_reaches_full_acceptance(served):
    """Draft model == target model ⇒ every proposal survives the verify
    (acceptance 1.0) and the stream is still exactly the greedy one —
    the strongest end-to-end check of draft-cache/target-cache lockstep
    (propose, catch-up step, and rollback)."""
    _, m, params, prompts = served
    base = np.asarray(ServingEngine(m, params, max_seq=64).generate(prompts[:2, :8], 6))
    eng = ServingEngine(m, params, max_seq=64)
    reqs = [Request(prompt=np.asarray(prompts[i, :8]), max_new_tokens=6) for i in range(2)]
    out = eng.serve(
        reqs, max_batch=2, spec=SpecConfig(k=4, drafter="model", draft_model=m, draft_params=params)
    )
    assert eng.stats.acceptance_rate == 1.0
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(out[r.rid], base[i])


# ---------------------------------------------------------------------------
# guards


def test_spec_guards(served):
    _, m, params, _ = served
    with pytest.raises(ValueError, match="temperature"):
        ServingEngine(m, params, max_seq=32, temperature=0.7).scheduler(
            2, spec=SpecConfig(k=4)
        )
    ssm = Model(get_config("mamba2-370m").reduced())
    sp, _ = ssm.init(jax.random.key(0))
    with pytest.raises(ValueError, match="rewindable"):
        ServingEngine(ssm, sp, max_seq=32).scheduler(2, spec=SpecConfig(k=4))
    with pytest.raises(ValueError, match="draft_model"):
        SpecConfig(k=4, drafter="model").make_drafter()
    from repro.core.plan import plan_for

    eng = ServingEngine(m, params, max_seq=32)
    plan = plan_for("spec-no-plan", lambda x: x, jnp.arange(4.0), granularity=1)
    eng.set_decode_plan(plan)
    with pytest.raises(ValueError, match="decode plans"):
        eng.scheduler(2, spec=SpecConfig(k=4))
    # ...and the late path: arming a plan on a spec scheduler must fail
    # loudly too, not silently never execute it
    eng2 = ServingEngine(m, params, max_seq=32)
    sched = eng2.scheduler(2, spec=SpecConfig(k=4))
    with pytest.raises(ValueError, match="decode plans"):
        sched.set_decode_plan(plan)


def test_submit_enforces_speculative_margin(served):
    """prompt + budget + K must fit the row: the rejected tail of the
    last verify transiently occupies K entries past the final length."""
    _, m, params, _ = served
    eng = ServingEngine(m, params, max_seq=16)
    req = Request(prompt=jnp.ones((8,), jnp.int32), max_new_tokens=6)
    with pytest.raises(ValueError, match="speculative margin"):
        eng.serve([req], max_batch=1, spec=SpecConfig(k=4))
    # the same request is fine without speculation
    out = eng.serve([Request(prompt=jnp.ones((8,), jnp.int32), max_new_tokens=6)], max_batch=1)
    assert len(next(iter(out.values()))) == 6


# ---------------------------------------------------------------------------
# drafters


def test_ngram_lookup_proposes_continuation():
    d = NGramDraftSource(k=4, ngram=(3, 2, 1))
    d.bind(max_batch=1, max_seq=64)
    # history ends in (1, 2) seen earlier, followed by 3, 4, ...
    hist = np.array([9, 1, 2, 3, 4, 5, 1, 2], np.int32)
    np.testing.assert_array_equal(d._lookup(hist), [3, 4, 5, 1])
    # a loop near the end cycle-extends: ... 7 8 7 8 → 7 8 7 8
    hist = np.array([5, 7, 8, 7, 8], np.int32)
    np.testing.assert_array_equal(d._lookup(hist), [7, 8, 7, 8])
    # no match anywhere → repeat the last token
    hist = np.array([3, 1, 4], np.int32)
    np.testing.assert_array_equal(d._lookup(hist), [4, 4, 4, 4])


# ---------------------------------------------------------------------------
# the advisory gate


def test_expected_tokens_per_round():
    assert expected_tokens_per_round(0.0, 4) == 1.0
    assert expected_tokens_per_round(1.0, 4) == 5.0
    assert expected_tokens_per_round(0.5, 2) == pytest.approx(1.75)


def test_advisor_picks_depth_by_expected_latency():
    tool = SpeculationAdvisorTool()
    # free drafts + high acceptance → speculate deep
    m = SpecMeasurement(
        draft_ms_per_token=0.0, verify_ms={0: 10.0, 8: 12.0}, acceptance_rate=0.9
    )
    k, gain, log = tool.choose(m)
    assert k == 8 and gain > 1.0 and "K=8" in log
    # zero acceptance → never speculate (every round still pays verify)
    m = SpecMeasurement(
        draft_ms_per_token=0.0, verify_ms={0: 10.0, 8: 12.0}, acceptance_rate=0.0
    )
    assert tool.choose(m)[0] == 0
    # drafts as expensive as the target → the gate declines
    m = SpecMeasurement(
        draft_ms_per_token=10.0, verify_ms={0: 10.0, 8: 12.0}, acceptance_rate=0.6
    )
    assert tool.choose(m)[0] == 0
    # moderate acceptance, cheap drafts: shallow beats deep (rejected
    # tails waste draft work at K=8)
    m = SpecMeasurement(
        draft_ms_per_token=0.5, verify_ms={0: 10.0, 8: 11.0}, acceptance_rate=0.5
    )
    k, gain, _ = tool.choose(m)
    assert k in (2, 4) and gain > 0.02
    # interpolated verify cost between measured depths
    assert m.verify_cost(4) == pytest.approx(10.5)
    assert m.verify_cost(0) == 10.0


def test_advisor_tool_is_silent_for_compute_regions(served):
    """As a pipeline stage the tool SKIPs (no stage-log line) unless a
    region carries a speculation measurement — compute-region advice,
    and the golden decisions, are untouched."""
    from repro.core import Aira, Workload
    from repro.core.adviser import Region
    from repro.core.overlap_model import CPU_HW

    def region(name):
        # chain-heavy VPU microtask, comfortably inside the smt2 band
        return Region(
            name, lambda x: x * 2.0, jnp.arange(1024, dtype=jnp.float32),
            task_flops=100.0, task_bytes=512.0, task_chain=16,
        )

    r1 = region("plain")
    d = Aira(hw=CPU_HW).advise(Workload("w", lambda: None, [r1])).decisions[0]
    assert d.accepted  # the pipeline reached (and silently skipped) speculate
    assert not any("speculate" in line for line in d.stage_log)

    r2 = region("spec")
    r2.spec_measurement = SpecMeasurement(
        draft_ms_per_token=0.0, verify_ms={0: 10.0, 8: 12.0}, acceptance_rate=0.9
    )
    d2 = Aira(hw=CPU_HW).advise(Workload("w", lambda: None, [r2])).decisions[0]
    assert any(line.startswith("speculate:") and "K=8" in line for line in d2.stage_log)


def test_advise_depth_end_to_end(served):
    """Probe-measure a self-repetitive workload and honor the decision:
    advise_depth returns a SpecConfig from the candidate set and
    serve(spec=...) runs it with the greedy stream unchanged."""
    _, m, params, prompts = served

    def workload():
        return [
            Request(prompt=np.asarray(prompts[i, :6]), max_new_tokens=10)
            for i in range(2)
        ]

    eng = ServingEngine(m, params, max_seq=64)
    base = eng.serve(workload(), max_batch=2)
    base_tok = [v for _, v in sorted(base.items())]
    spec, meas, log = advise_depth(eng, workload, ks=(0, 2, 4), max_batch=2)
    assert spec.k in (0, 2, 4)
    assert 0.0 <= meas.acceptance_rate <= 1.0
    assert "K=" in log
    out = eng.serve(workload(), max_batch=2, spec=spec)
    for a, b in zip(base_tok, [v for _, v in sorted(out.items())]):
        np.testing.assert_array_equal(a, b)


def test_serving_spec_stages_resolve():
    """Every SERVING_SPEC stage's tool path names a real symbol — the
    stage table cannot silently drift from the code it describes (the
    same contract the AIRA_SPEC name test pins for the compute
    pipeline)."""
    import importlib

    from repro.core.spec import SERVING_SPEC

    assert [s.name for s in SERVING_SPEC] == [
        "draft", "verify", "rollback", "speculate",
    ]
    for stage in SERVING_SPEC:
        parts = stage.tool.split(".")
        obj, i = None, len(parts)
        while i > 0:  # longest importable module prefix, then attrs
            try:
                obj = importlib.import_module("repro." + ".".join(parts[:i]))
                break
            except ImportError:
                i -= 1
        assert obj is not None, stage.tool
        for attr in parts[i:]:
            obj = getattr(obj, attr)  # raises if the path drifted


def test_stats_spec_accounting_resets(served):
    _, m, params, prompts = served
    eng = ServingEngine(m, params, max_seq=64)
    eng.serve(
        [Request(prompt=np.asarray(prompts[0, :8]), max_new_tokens=5)],
        max_batch=1, spec=SpecConfig(k=2),
    )
    assert eng.stats.spec_steps > 0 and eng.stats.spec_proposed > 0
    assert len(eng.stats.draft_ms) == eng.stats.spec_steps
    assert len(eng.stats.verify_ms) == eng.stats.spec_steps
    eng.stats.reset()
    assert eng.stats.spec_steps == 0 and eng.stats.spec_proposed == 0
    assert not eng.stats.draft_ms and not eng.stats.verify_ms
    assert "speculative" not in eng.stats.serving_summary()
