"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container without hypothesis: deterministic fallback
    from _hypothesis_stub import given, settings, strategies as st

from repro.configs.base import pad_to_multiple
from repro.core.overlap_model import CPU_HW, Microtask, OverlapModel
from repro.core.relic import relic_pfor

MODEL = OverlapModel(CPU_HW)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 500),
    g=st.integers(1, 64),
    width=st.integers(1, 16),
)
def test_relic_pfor_equals_vmap(n, g, width):
    fn = lambda x: jnp.tanh(x).sum() * 2.0
    xs = jnp.arange(n * width, dtype=jnp.float32).reshape(n, width) / 97.0
    got = relic_pfor(fn, xs, granularity=g)
    want = jax.vmap(fn)(xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@settings(max_examples=50, deadline=None)
@given(
    flops=st.floats(1.0, 1e6),
    nbytes=st.floats(1.0, 1e6),
    chain=st.integers(0, 512),
    n=st.integers(1, 10_000),
)
def test_overlap_model_bounds(flops, nbytes, chain, n):
    t = Microtask(flops=flops, bytes=nbytes, chain=chain, vector=True)
    p = MODEL.predict(t, n)
    c, c_s, m_lat, m_bw = MODEL._components(t)
    # serial is exactly n per-task times
    assert p.serial == (c_s + m_lat + m_bw) * n
    # no schedule beats its shared-resource floors
    assert p.smt2 >= n * m_bw * (1 + CPU_HW.bw_contention) - 1e-12
    assert p.smt2 >= n * c * (1 + CPU_HW.contention) - 1e-12
    # smt2 speedup is bounded by 2× (two streams)
    assert p.serial / p.smt2 <= 2.0 + 1e-9


@settings(max_examples=50, deadline=None)
@given(x=st.integers(1, 10**7), m=st.sampled_from([8, 64, 128, 256, 2048]))
def test_pad_to_multiple(x, m):
    p = pad_to_multiple(x, m)
    assert p >= x and p % m == 0 and p - x < m


@settings(max_examples=20, deadline=None)
@given(
    dim=st.sampled_from([48, 64, 96, 100, 128, 576, 1024]),
    axes=st.sampled_from([("batch",), ("mlp",), ("heads",), ("vocab",)]),
)
def test_sharding_spec_divisibility(dim, axes):
    """spec() never assigns a mesh axis that does not divide the dim."""
    import subprocess, sys, os

    # cheap structural check without a big mesh: rules built on a fake
    # mesh via dataclass stub
    from repro.parallel.sharding import ShardingRules
    from repro.configs import get_config

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = ShardingRules.__new__(ShardingRules)
    rules.mesh = FakeMesh()
    rules.cfg = get_config("smollm-135m")
    rules.fallbacks = []
    rules.table = {
        "batch": ("data",), "mlp": "model", "heads": "model", "vocab": "model",
    }
    spec = rules.spec(axes, (dim,))
    assigned = spec[0]
    if assigned is not None:
        names = (assigned,) if isinstance(assigned, str) else assigned
        size = 1
        for nm in names:
            size *= FakeMesh.shape[nm]
        assert dim % size == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_adamw_descends_quadratic(seed):
    from repro.train.optimizer import AdamW

    key = jax.random.key(seed)
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros((8,))}
    opt = AdamW(lr=0.05, warmup_steps=1, total_steps=100, weight_decay=0.0)
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 0.2 * l0


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000))
def test_data_pipeline_deterministic(step):
    from repro.configs import get_config
    from repro.data import SyntheticLMData

    cfg = get_config("smollm-135m").reduced()
    d = SyntheticLMData(cfg, batch=2, seq=16, seed=3)
    a = d.batch_at(step)
    b = d.batch_at(step)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert int(a["tokens"].max()) < cfg.vocab_size
