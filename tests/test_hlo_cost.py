"""Trip-count-aware HLO cost model vs unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_cost import analyze


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_match_unrolled():
    def make(unroll):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None

            y, _ = jax.lax.scan(body, x, ws, unroll=unroll)
            return y

        return f

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    c_s = analyze(_text(make(False), x, ws))
    c_u = analyze(_text(make(True), x, ws))
    true = 12 * 2 * 128**3
    assert c_s.flops == pytest.approx(true, rel=1e-6)
    assert c_u.flops == pytest.approx(true, rel=1e-6)


def test_nested_scan_trip_counts():
    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None

            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None

        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    c = analyze(_text(f, x, ws))
    assert c.flops == pytest.approx(7 * 5 * 2 * 64**3, rel=1e-6)


def test_dot_general_contracted_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = analyze(_text(f, a, b))
    assert c.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=1e-6)


def test_scan_param_slicing_not_overcounted():
    """The scan body reads 1/L of the stacked weights per iteration; the
    walker must NOT charge the full stack every iteration."""
    L, D = 16, 64

    def f(x, ws):
        def body(c, w):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((8, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    c = analyze(_text(f, x, ws))
    full_stack = L * D * D * 4
    # total weight traffic ≈ one pass over the stack (± small overheads),
    # NOT L × stack
    assert c.bytes < 4 * full_stack


def test_collectives_multiplied_by_trips():
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.hlo_cost import analyze
try:
    mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
except AttributeError:  # jax 0.4.x: no AxisType
    mesh = jax.make_mesh((2,4), ("data","model"))
def f(x, ws):
    def body(c, w):
        y = jnp.tanh(c @ w)
        return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P("data", None))), None
    y, _ = jax.lax.scan(body, x, ws)
    return y
x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
ws = jax.ShapeDtypeStruct((10, 64, 64, ), jnp.float32)
with mesh:
    comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                    NamedSharding(mesh, P(None, None, "model")))).lower(x, ws).compile()
c = analyze(comp.as_text())
counts = dict(c.collective_counts)
assert sum(counts.values()) >= 10, counts   # per-layer collective × trip count
print("OK", counts)
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
