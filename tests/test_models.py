"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, output shapes + finite values; decode ≡ prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.data import SyntheticLMData
from repro.models import Model
from repro.train import AdamW, make_train_step


def _batch(cfg, B=2, S=32):
    data = SyntheticLMData(cfg, batch=B, seq=S)
    return data.batch_at(0)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_loss_and_step(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params, axes = m.init(jax.random.key(0))
    assert jax.tree_util.tree_structure(params) == jax.tree_util.tree_structure(
        jax.tree.map(lambda _: 0, axes, is_leaf=lambda x: isinstance(x, tuple))
    )
    batch = _batch(cfg)
    opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(m, opt))
    params2, opt_state, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["loss"]) < 3 * np.log(cfg.padded_vocab)
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    B = 2
    cache = m.init_cache(B, 64)
    logits, cache2 = jax.jit(m.decode_step)(params, cache, jnp.ones((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert int(cache2["len"][0]) == 1


@pytest.mark.parametrize("arch", ["smollm-135m", "granite-moe-1b-a400m", "mamba2-370m", "zamba2-2.7b"])
def test_decode_matches_prefill(arch):
    """Greedy decode over a prompt suffix == teacher-forced forward.

    MoE: capacity dropping in the train/prefill dispatch path is expected
    behaviour but breaks exactness — compare in the drop-free regime
    (capacity_factor = E/k ⇒ every expert can absorb every token)."""
    import dataclasses

    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k
        )
    m = Model(cfg)
    params, _ = m.init(jax.random.key(1))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    # full forward logits at position S-1
    x, _, _ = m.forward(params, toks)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    want = jnp.einsum("bd,dv->bv", x[:, -1], head)
    # prefill S-1 tokens, decode token S-1
    logits_p, cache = jax.jit(lambda p, t: m.prefill(p, t, 32))(params, toks[:, : S - 1])
    got, _ = jax.jit(m.decode_step)(params, cache, toks[:, S - 1 :])
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2, rtol=2e-2
    )


def test_vlm_frontend_stub():
    cfg = get_config("internvl2-2b").reduced()
    m = Model(cfg)
    params, _ = m.init(jax.random.key(0))
    B, S = 2, 32
    data = SyntheticLMData(cfg, batch=B, seq=S)
    batch = data.batch_at(0)
    assert batch["tokens"].shape == (B, S - cfg.n_frontend_tokens)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))


def test_moe_reference_routing_properties():
    from repro.models.moe import _moe_reference, init_moe

    cfg = get_config("granite-moe-1b-a400m").reduced()
    params, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    y, aux = _moe_reference(x, params, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0  # load-balance loss is positive


def test_param_counts_match_published():
    for arch, lo, hi in [
        ("dbrx-132b", 125e9, 135e9),
        ("phi3-medium-14b", 13.5e9, 15.5e9),
        ("internlm2-20b", 19e9, 21e9),
        ("smollm-135m", 0.125e9, 0.145e9),
        ("mamba2-370m", 0.34e9, 0.40e9),
    ]:
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_int8_kv_cache_decode_accuracy():
    """kv_quant=True decode logits ≈ bf16-cache decode (≤5% rel err)."""
    import dataclasses

    for arch in ("smollm-135m", "zamba2-2.7b"):
        cfg = get_config(arch).reduced()
        cfgq = dataclasses.replace(cfg, kv_quant=True)
        m, mq = Model(cfg), Model(cfgq)
        params, _ = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(2), (2, 12), 0, cfg.vocab_size)
        _, cache = jax.jit(lambda p, t: m.prefill(p, t, 32))(params, toks[:, :-1])
        _, cacheq = jax.jit(lambda p, t: mq.prefill(p, t, 32))(params, toks[:, :-1])
        g1, _ = jax.jit(m.decode_step)(params, cache, toks[:, -1:])
        g2, _ = jax.jit(mq.decode_step)(params, cacheq, toks[:, -1:])
        rel = float(jnp.abs(g1 - g2).max()) / (float(jnp.abs(g1).max()) + 1e-9)
        assert rel < 0.05, (arch, rel)


def test_flat_tp_attention_equivalence():
    """attn_flat_tp=True (head-agnostic sharded projections) computes
    exactly the same forward as the standard head layout."""
    import dataclasses

    cfg = get_config("smollm-135m").reduced()
    cfgf = dataclasses.replace(cfg, attn_flat_tp=True)
    m, mf = Model(cfg), Model(cfgf)
    params, _ = m.init(jax.random.key(0))
    lp = dict(params["layers"])
    at = dict(lp["attn"])
    L, D = at["wq"].shape[0], cfg.d_model
    lp["attn"] = {
        "wq": at["wq"].reshape(L, D, -1),
        "wk": at["wk"].reshape(L, D, -1),
        "wv": at["wv"].reshape(L, D, -1),
        "wo": at["wo"].reshape(L, -1, D),
    }
    pf = dict(params, layers=lp)
    toks = jax.random.randint(jax.random.key(2), (2, 16), 0, cfg.vocab_size)
    x1, _, _ = m.forward(params, toks)
    x2, _, _ = mf.forward(pf, toks)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), atol=1e-5)
